"""Serving subsystem: scheduler invariants, decode parity, metrics math,
traffic-simulator properties."""
import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cache_layout import CacheLayout
from repro.config import get_arch, reduced
from repro.models import transformer as tf
from repro.serving import engine as eng
from repro.serving import metrics as sm
from repro.serving import traffic


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_cache():
    # On CPU the full tier-1 run accumulates hundreds of compiled
    # executables by the time this module's engine matrix runs; dropping
    # them keeps XLA:CPU within its code-region budget.
    jax.clear_caches()


# ---------------------------------------------------------------------------
# metrics: percentile + summarize math
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.0, 1000.0), min_size=1, max_size=40),
       st.sampled_from([0, 25, 50, 75, 90, 95, 99, 100]))
def test_percentile_matches_numpy(xs, q):
    assert sm.percentile(xs, q) == pytest.approx(
        float(np.percentile(np.asarray(xs), q)), rel=1e-9, abs=1e-9)


def test_summarize_throughput_and_slo():
    recs = []
    for i in range(4):
        r = sm.RequestRecord(rid=i, slo_name="interactive",
                             ttft_slo_s=0.5, tpot_slo_s=0.1,
                             arrival=0.0, admitted=0.1)
        r.first_token = 0.1 * (i + 1)          # 0.1 .. 0.4 TTFT
        r.finished = r.first_token + 0.05 * 4  # 5 tokens, tpot 0.05
        r.tokens_out = 5
        recs.append(r)
    s = sm.summarize(recs, elapsed_s=2.0)
    assert s["tokens_out"] == 20
    assert s["throughput_tok_s"] == pytest.approx(10.0)
    assert s["ttft_s"]["p50"] == pytest.approx(0.25)
    # all meet tpot (0.05 <= 0.1); all meet ttft (<= 0.5)
    assert s["slo"]["interactive"]["attainment"] == pytest.approx(1.0)
    recs[3].first_token = 0.9                  # blow the TTFT SLO for one
    recs[3].finished = 0.9 + 0.2
    s = sm.summarize(recs, elapsed_s=2.0)
    assert s["slo"]["interactive"]["attainment"] == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# scheduler invariants on a deterministic toy backend (no jax model)
# ---------------------------------------------------------------------------

class CountingBackend:
    """Next token = (last token + 1) % V; no real cache."""

    V = 32

    def init_cache(self, n_slots, max_len):
        return {"len": np.zeros(n_slots, np.int64)}

    def prefill(self, cache, tokens, true_len, slot):
        logits = np.zeros(self.V, np.float32)
        logits[(int(tokens[0, true_len - 1]) + 1) % self.V] = 1.0
        return logits, cache

    def decode(self, cache, tokens):
        B = tokens.shape[0]
        logits = np.zeros((B, 1, self.V), np.float32)
        for b in range(B):
            logits[b, 0, (int(tokens[b, 0]) + 1) % self.V] = 1.0
        return logits, cache


def _toy_workload(n=24, seed=0, eos_id=-1, arrival_rate=200.0):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n))
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, 10))
        prompt = tuple(int(t) for t in
                       rng.integers(0, CountingBackend.V, plen))
        reqs.append(traffic.Request(
            rid=i, user_id=i, prompt=prompt,
            max_new_tokens=int(rng.integers(2, 9)),
            arrival=float(arrivals[i]), eos_id=eos_id))
    return reqs


def _toy_engine(refill="continuous", n_slots=3, queue_capacity=64,
                max_len=64):
    clock = traffic.Clock(fixed_decode_s=0.01, fixed_prefill_s=0.02)
    ecfg = eng.EngineConfig(n_slots=n_slots, max_len=max_len,
                            queue_capacity=queue_capacity, refill=refill)
    return eng.ServingEngine(CountingBackend(), ecfg, clock)


def test_scheduler_serves_everything_without_slot_leaks():
    reqs = _toy_workload()
    engine = _toy_engine()
    outputs, records, summary = engine.run(reqs)
    # queue drained, no occupied slots left behind
    assert not engine.queue
    assert all(r is None for r in engine.slot_req)
    assert summary["finished"] == len(reqs) and summary["rejected"] == 0
    # each request served exactly once with its full token budget,
    # and the counting model's tokens are exact (parity with "sequential")
    for r in reqs:
        want = [(r.prompt[-1] + 1 + i) % CountingBackend.V
                for i in range(r.max_new_tokens)]
        assert outputs[r.rid] == want
    # lifecycle timestamps are ordered
    for rec in records:
        assert rec.arrival <= rec.admitted <= rec.first_token <= rec.finished


def test_bounded_queue_rejects_overflow():
    reqs = [dataclasses.replace(r, arrival=0.0) for r in _toy_workload(n=12)]
    engine = _toy_engine(n_slots=1, queue_capacity=3)
    outputs, records, summary = engine.run(reqs)
    # the whole burst arrives before any slot frees, so only the bounded
    # queue's capacity is admitted; the rest are rejected
    assert summary["rejected"] == 12 - 3
    assert summary["finished"] == 3
    rejected = {r.rid for r in records if r.rejected}
    assert all(rid not in outputs for rid in rejected)


def test_oversized_prompt_rejected_not_crashed():
    ok = _toy_workload(n=2)[0]
    too_long = traffic.Request(rid=99, user_id=0,
                               prompt=tuple(range(70)), max_new_tokens=4,
                               arrival=0.0)
    engine = _toy_engine(max_len=64)
    outputs, records, summary = engine.run([ok, too_long])
    assert summary["rejected"] == 1
    assert ok.rid in outputs and 99 not in outputs


def test_early_eos_truncates_generation():
    prompt = (5, 6, 7)
    # counting model emits 8, 9, 10, ... -> eos at the 3rd token
    req = traffic.Request(rid=0, user_id=0, prompt=prompt,
                          max_new_tokens=10, arrival=0.0, eos_id=10)
    outputs, records, _ = _toy_engine().run([req])
    assert outputs[0] == [8, 9, 10]
    assert records[0].tokens_out == 3


def test_eos_on_first_token_frees_slot_immediately():
    req = traffic.Request(rid=0, user_id=0, prompt=(3,),
                          max_new_tokens=10, arrival=0.0, eos_id=4)
    engine = _toy_engine()
    outputs, records, summary = engine.run([req])
    assert outputs[0] == [4]
    assert summary["decode_steps"] == 0
    assert all(r is None for r in engine.slot_req)


def test_continuous_refill_beats_static_on_steps_and_throughput():
    reqs = _toy_workload(n=30, seed=3)
    sums = {}
    for refill in ("static", "continuous"):
        engine = _toy_engine(refill=refill)
        _, _, sums[refill] = engine.run(reqs)
        assert sums[refill]["finished"] == len(reqs)
    # static idles finished slots until the whole batch drains; with mixed
    # max_new_tokens continuous needs strictly fewer decode steps and
    # delivers more tokens/s at the same slot count
    assert (sums["continuous"]["decode_steps"]
            < sums["static"]["decode_steps"])
    assert (sums["continuous"]["throughput_tok_s"]
            > sums["static"]["throughput_tok_s"])


def test_static_refill_waits_for_full_drain():
    reqs = [dataclasses.replace(r, arrival=0.0)
            for r in _toy_workload(n=6, seed=1)]
    engine = _toy_engine(refill="static", n_slots=3)

    started_at = {}
    orig = engine._start

    def spy(slot, req, rec):
        started_at[req.rid] = engine.clock.now
        return orig(slot, req, rec)

    engine._start = spy
    engine.run(reqs)
    assert len(started_at) == 6
    # 6 requests on 3 slots = two admission waves of 3: the barrier means
    # the 4th start happens only after every wave-1 request finished
    by_start = sorted(started_at, key=started_at.get)
    first_wave, second_wave_start = by_start[:3], started_at[by_start[3]]
    finish = {rec.rid: rec.finished for rec in engine.records}
    assert all(finish[r] <= second_wave_start + 1e-9 for r in first_wave)


# ---------------------------------------------------------------------------
# per-request sampling (temperature / top-k, per-slot RNG keys)
# ---------------------------------------------------------------------------

def _sampled_workload(n=12, temperature=0.0, top_k=0, seed=0):
    return [dataclasses.replace(r, temperature=temperature, top_k=top_k)
            for r in _toy_workload(n=n, seed=seed)]


def test_temperature_zero_is_greedy():
    reqs = _sampled_workload(temperature=0.0)
    outputs, _, _ = _toy_engine().run(reqs)
    for r in reqs:
        want = [(r.prompt[-1] + 1 + i) % CountingBackend.V
                for i in range(r.max_new_tokens)]
        assert outputs[r.rid] == want


def test_top_k_one_is_greedy_at_any_temperature():
    reqs = _sampled_workload(temperature=3.0, top_k=1)
    outputs, _, _ = _toy_engine().run(reqs)
    for r in reqs:
        want = [(r.prompt[-1] + 1 + i) % CountingBackend.V
                for i in range(r.max_new_tokens)]
        assert outputs[r.rid] == want


def test_sampling_deviates_from_greedy_and_is_reproducible():
    # CountingBackend logits are one-hot 0/1: at T=5 the argmax carries
    # almost no extra mass, so sampled streams diverge from greedy
    reqs = _sampled_workload(n=16, temperature=5.0)
    greedy = {r.rid: [(r.prompt[-1] + 1 + i) % CountingBackend.V
                      for i in range(r.max_new_tokens)] for r in reqs}
    out1, _, _ = _toy_engine().run(reqs)
    out2, _, _ = _toy_engine().run(reqs)
    assert out1 == out2, "same seed must reproduce the same streams"
    assert any(out1[r.rid] != greedy[r.rid] for r in reqs)
    # a different engine sampling seed gives a different workload
    ecfg = eng.EngineConfig(n_slots=3, max_len=64, sample_seed=99)
    clock = traffic.Clock(fixed_decode_s=0.01, fixed_prefill_s=0.02)
    out3, _, _ = eng.ServingEngine(CountingBackend(), ecfg, clock).run(reqs)
    assert out3 != out1


def test_sampled_stream_independent_of_slot_count():
    """The RNG key is (seed, rid, token-index): batch composition and slot
    placement cannot change a request's sampled tokens."""
    reqs = _sampled_workload(n=10, temperature=2.0, top_k=8)
    outs = []
    for n_slots in (1, 3):
        outputs, _, _ = _toy_engine(n_slots=n_slots).run(reqs)
        outs.append(outputs)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# SLO-aware admission: shed batch tier before interactive
# ---------------------------------------------------------------------------

def _tiered_burst(n_interactive, n_batch):
    reqs = []
    for i in range(n_interactive + n_batch):
        tier = (traffic.INTERACTIVE_TIER if i < n_interactive
                else traffic.BATCH_TIER)
        reqs.append(traffic.Request(
            rid=i, user_id=i, prompt=(3, 4, 5), max_new_tokens=4,
            arrival=0.0, slo=tier))
    return reqs


def test_interactive_arrival_sheds_newest_batch_request():
    # 1 slot, queue of 2; the whole burst arrives before any slot frees:
    # batch rids 0,1 queue, rid 2 finds the queue full (batch cannot shed),
    # then interactive rids 3,4 each displace the newest queued batch entry
    reqs = _tiered_burst(0, 3) + _tiered_burst(2, 0)
    for i, r in enumerate(reqs):
        reqs[i] = dataclasses.replace(r, rid=i, user_id=i)
    engine = _toy_engine(n_slots=1, queue_capacity=2)
    outputs, records, summary = engine.run(reqs)
    by_rid = {r.rid: r for r in records}
    assert summary["rejected"] == 3
    assert all(by_rid[r].rejected for r in (0, 1, 2))
    assert not by_rid[3].rejected and not by_rid[4].rejected
    assert 3 in outputs and 4 in outputs and summary["finished"] == 2


def test_batch_arrival_never_sheds_interactive():
    reqs = _tiered_burst(2, 0) + _tiered_burst(0, 2)
    for i, r in enumerate(reqs):
        reqs[i] = dataclasses.replace(r, rid=i, user_id=i)
    engine = _toy_engine(n_slots=1, queue_capacity=2)
    _, records, summary = engine.run(reqs)
    by_rid = {r.rid: r for r in records}
    # interactive 0,1 fill the queue; batch 2,3 find it full and cannot
    # evict interactive entries
    assert by_rid[2].rejected and by_rid[3].rejected
    assert not by_rid[0].rejected and not by_rid[1].rejected
    assert summary["finished"] == 2


def test_interactive_tier_pops_before_batch():
    # one slot; a batch request and an interactive request both queued:
    # the interactive one must start first even though it arrived later
    reqs = [
        traffic.Request(rid=0, user_id=0, prompt=(3,), max_new_tokens=6,
                        arrival=0.0, slo=traffic.BATCH_TIER),
        traffic.Request(rid=1, user_id=1, prompt=(4,), max_new_tokens=2,
                        arrival=0.0, slo=traffic.BATCH_TIER),
        traffic.Request(rid=2, user_id=2, prompt=(5,), max_new_tokens=2,
                        arrival=0.001, slo=traffic.INTERACTIVE_TIER),
    ]
    engine = _toy_engine(n_slots=1)
    _, records, _ = engine.run(reqs)
    by_rid = {r.rid: r for r in records}
    assert by_rid[2].admitted < by_rid[1].admitted


# ---------------------------------------------------------------------------
# real-model parity: continuous batch decode == sequential decode
# ---------------------------------------------------------------------------

def _real_requests(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 12))
        reqs.append(traffic.Request(
            rid=i, user_id=i,
            prompt=tuple(int(t) for t in
                         rng.integers(3, cfg.vocab_size, plen)),
            max_new_tokens=int(rng.integers(3, 8)), arrival=0.0))
    return reqs


def _sequential_greedy(cfg, params, req, max_len=64):
    ctx = tf.ModelCtx(attn_chunk=8)
    cache = tf.init_cache(cfg, 1, max_len)
    batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
    logits, cache = tf.prefill_into_cache(cfg, params, batch, cache, ctx)
    toks = [int(jnp.argmax(logits[0]))]
    while len(toks) < req.max_new_tokens:
        lg, cache = tf.decode_step(cfg, params, cache,
                                   jnp.asarray([[toks[-1]]], jnp.int32), ctx)
        toks.append(int(jnp.argmax(lg[0, 0])))
    return toks


def test_continuous_batching_matches_sequential_decode():
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _real_requests(cfg)
    ecfg = eng.EngineConfig(n_slots=2, max_len=64)
    outputs, _, summary = eng.serve(cfg, params, reqs, ecfg)
    assert summary["finished"] == len(reqs)
    for req in reqs:
        assert outputs[req.rid] == _sequential_greedy(cfg, params, req), \
            f"request {req.rid} diverged from sequential decode"


def test_int8_kv_backend_tracks_native_logits():
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    native = eng.NativeBackend(cfg, params)
    quant = eng.Int8KVBackend(cfg, params)
    cache_n = native.init_cache(2, 64)
    cache_q = quant.init_cache(2, 64)
    rng = np.random.default_rng(1)
    for slot in range(2):
        plen = int(rng.integers(6, 12))
        padded = np.zeros((1, 16), np.int32)
        padded[0, :plen] = rng.integers(3, cfg.vocab_size, plen)
        ln, cache_n = native.prefill(cache_n, padded, plen, slot)
        lq, cache_q = quant.prefill(cache_q, padded, plen, slot)
        # prefill runs the unquantized forward in both backends
        np.testing.assert_allclose(np.asarray(ln), np.asarray(lq),
                                   atol=1e-5, rtol=1e-5)
    # decode against the quantized cache: logits stay within a small
    # fraction of the native logit spread, greedy argmax identical
    toks = jnp.asarray([[5], [9]], jnp.int32)
    for _ in range(4):
        lg_n, cache_n = native.decode(cache_n, toks)
        lg_q, cache_q = quant.decode(cache_q, toks)
        spread = float(jnp.max(lg_n) - jnp.min(lg_n))
        err = float(jnp.max(jnp.abs(lg_n - lg_q)))
        assert err <= 0.05 * spread, f"int8 logit error {err} vs {spread}"
        assert (jnp.argmax(lg_n[:, 0], -1)
                == jnp.argmax(lg_q[:, 0], -1)).all()
        toks = jnp.argmax(lg_n[:, -1:], -1).astype(jnp.int32)


def test_family_registry_and_int8_gating():
    """Every family resolves a backend; the fused int8 path stays pinned to
    the uniform family; int8 on a KV-free family is a clear error."""
    assert set(eng.FAMILY_BACKENDS) == {"uniform", "gemma", "jamba",
                                        "rwkv6", "whisper"}
    cfg = dataclasses.replace(reduced(get_arch("rwkv6-1.6b")),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    assert isinstance(eng.make_backend(cfg, params), eng.NativeBackend)
    with pytest.raises(NotImplementedError):
        eng.Int8KVBackend(cfg, params)       # fused path is uniform-only
    with pytest.raises(ValueError):     # rwkv6 has no KV
        eng.make_backend(cfg, params, layout=CacheLayout(kv_bits=8))
    cfg_g = dataclasses.replace(reduced(get_arch("gemma3-1b")),
                                dtype="float32")
    params_g = tf.init_params(jax.random.PRNGKey(0), cfg_g)
    assert isinstance(eng.make_backend(cfg_g, params_g,
                                       layout=CacheLayout(kv_bits=8)),
                      eng.Int8KVSlots)


# ---------------------------------------------------------------------------
# family-polymorphic DecodeState: every family through the same engine
# ---------------------------------------------------------------------------

FAMILY_ARCHS = {"uniform": "olmo-1b", "gemma": "gemma3-1b",
                "jamba": "jamba-v0.1-52b", "rwkv6": "rwkv6-1.6b",
                "whisper": "whisper-medium"}


def _family_setup(fam, seed=0):
    cfg = dataclasses.replace(reduced(get_arch(FAMILY_ARCHS[fam])),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(4):
        plen = int(rng.integers(4, 12))
        frames = None
        if cfg.encoder_layers:
            f = rng.normal(0, 0.02, (cfg.encoder_frames, cfg.d_model))
            frames = tuple(tuple(float(x) for x in row) for row in f)
        reqs.append(traffic.Request(
            rid=i, user_id=i,
            prompt=tuple(int(t) for t in
                         rng.integers(3, cfg.vocab_size, plen)),
            max_new_tokens=int(rng.integers(3, 8)), arrival=0.0,
            frames=frames))
    return cfg, params, reqs


@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
def test_continuous_batching_matches_sequential_per_family(fam):
    """Slot composition must never change a request's greedy stream: the
    continuous engine over mixed slots produces the same tokens as serving
    each request alone in a 1-slot engine (same prefill buckets)."""
    cfg, params, reqs = _family_setup(fam)
    backend = eng.make_backend(cfg, params)      # shared jit cache
    outputs, _, summary = eng.ServingEngine(
        backend, eng.EngineConfig(n_slots=3, max_len=64)).run(reqs)
    assert summary["finished"] == len(reqs)
    assert summary["tokens_out"] > 0
    for req in reqs:
        solo, _, _ = eng.ServingEngine(
            backend, eng.EngineConfig(n_slots=1, max_len=64)).run([req])
        assert outputs[req.rid] == solo[req.rid], \
            f"{fam} request {req.rid} diverged from sequential decode"


@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
def test_prefill_into_slot_matches_full_forward(fam):
    """Per-slot prefill + cached decode tracks a from-scratch full forward
    over the growing sequence — the state scattered into a slot (ring rows,
    recurrent states, cross-KV) is exactly the prompt's state.  MoE capacity
    is uncapped so padded and exact-length runs route identically."""
    ctx = tf.ModelCtx(attn_chunk=8, moe_capacity_factor=8.0)
    cfg = dataclasses.replace(reduced(get_arch(FAMILY_ARCHS[fam])),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    plen, s_pad = 12, 16                 # > gemma window 8: ring wraps
    prompt = rng.integers(3, cfg.vocab_size, plen)
    padded = np.zeros((1, s_pad), np.int32)
    padded[0, :plen] = prompt
    frames = None
    if cfg.encoder_layers:
        frames = jnp.asarray(rng.normal(0, 0.02,
                                        (1, cfg.encoder_frames, cfg.d_model)),
                             jnp.float32)

    def ref_logits(all_tokens):
        b = {"tokens": jnp.asarray([all_tokens], jnp.int32)}
        if frames is not None:
            b["frames"] = frames
        return tf.forward(cfg, params, b, ctx)[0][0, -1]

    cache = tf.init_slots(cfg, 2, 32)
    lg, cache = tf.prefill_into_slot(cfg, params, cache, jnp.asarray(padded),
                                     jnp.int32(plen), jnp.int32(1), ctx,
                                     frames=frames)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_logits(
        list(prompt))), atol=2e-3, rtol=2e-3)
    toks = [int(jnp.argmax(lg))]
    for _ in range(6):
        t2 = np.zeros((2, 1), np.int32)
        t2[1, 0] = toks[-1]
        lg2, cache = tf.decode_step(cfg, params, cache, jnp.asarray(t2), ctx)
        rl = ref_logits(list(prompt) + toks)
        np.testing.assert_allclose(np.asarray(lg2[1, 0]), np.asarray(rl),
                                   atol=2e-3, rtol=2e-3)
        toks.append(int(jnp.argmax(lg2[1, 0])))


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "qwen3-moe-30b-a3b"])
def test_moe_prefill_independent_of_pad_contents(arch):
    """Pad positions are masked out of MoE routing: garbage in the pad
    region can never evict a real token from its expert, so prefill logits
    and the scattered state are bit-identical whatever the padding holds.
    Capacity is deliberately tight (0.5) and the pad region wide — without
    the routing mask, pad tokens' expert slots queue ahead of real tokens'
    k=1 slots and this test diverges."""
    cfg = dataclasses.replace(reduced(get_arch(arch)), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    ctx = tf.ModelCtx(attn_chunk=8, moe_capacity_factor=0.5)
    rng = np.random.default_rng(3)
    plen, s_pad = 9, 32
    prompt = rng.integers(3, cfg.vocab_size, plen)
    outs = []
    for fill in (0, 1):                      # pad with zeros vs garbage
        padded = np.full((1, s_pad), 0, np.int32)
        if fill:
            padded[0] = rng.integers(3, cfg.vocab_size, s_pad)
        padded[0, :plen] = prompt
        cache = tf.init_slots(cfg, 1, 32)
        lg, cache = tf.prefill_into_slot(
            cfg, params, cache, jnp.asarray(padded), jnp.int32(plen),
            jnp.int32(0), ctx)
        toks = [int(jnp.argmax(lg))]
        for _ in range(3):
            lg2, cache = tf.decode_step(
                cfg, params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
                ctx)
            toks.append(int(jnp.argmax(lg2[0, 0])))
        outs.append((np.asarray(lg), toks))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1]


def test_gemma_ring_buffer_wraparound():
    """Regression: prompt + generated tokens exceed the sliding window, so
    local-layer ring rows wrap during BOTH prefill scatter and decode; the
    cached stream must still match full re-forward sliding-window attention
    token for token."""
    cfg = dataclasses.replace(reduced(get_arch("gemma3-1b")),
                              dtype="float32")
    assert cfg.sliding_window == 8
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    plen = 13                            # prompt alone already wraps
    prompt = rng.integers(3, cfg.vocab_size, plen)
    padded = np.zeros((1, 16), np.int32)
    padded[0, :plen] = prompt
    ctx = tf.ModelCtx(attn_chunk=8)
    cache = tf.init_slots(cfg, 1, 32)
    lg, cache = tf.prefill_into_slot(cfg, params, cache, jnp.asarray(padded),
                                     jnp.int32(plen), jnp.int32(0), ctx)
    toks = [int(jnp.argmax(lg))]
    for _ in range(10):                  # 13 + 10 = 23 >> window 8
        lg, cache = tf.decode_step(cfg, params, cache,
                                   jnp.asarray([[toks[-1]]], jnp.int32), ctx)
        toks.append(int(jnp.argmax(lg[0, 0])))
    want = []
    seq = list(prompt)
    for _ in range(11):
        full = tf.forward(cfg, params, {"tokens": jnp.asarray([seq])},
                          ctx)[0][0, -1]
        want.append(int(jnp.argmax(full)))
        seq.append(want[-1])
    assert toks == want


def test_int8_slots_composition_tracks_native():
    """The generic int8-KV composition (gemma ring buffers + whisper
    cross-KV) stays close to native logits and preserves greedy argmax —
    and repeated requantization of untouched rows does not drift."""
    for fam in ("gemma", "whisper"):
        cfg, params, reqs = _family_setup(fam, seed=3)
        native = eng.make_backend(cfg, params)
        quant = eng.make_backend(cfg, params,
                                 layout=CacheLayout(kv_bits=8))
        frames = (np.asarray(reqs[0].frames, np.float32)
                  if reqs[0].frames is not None else None)
        cache_n = native.init_slots(2, 64)
        cache_q = quant.init_slots(2, 64)
        rng = np.random.default_rng(4)
        for slot in range(2):
            plen = int(rng.integers(6, 12))
            padded = np.zeros((1, 16), np.int32)
            padded[0, :plen] = rng.integers(3, cfg.vocab_size, plen)
            ln, cache_n = native.prefill(cache_n, padded, plen, slot,
                                         frames=frames)
            lq, cache_q = quant.prefill(cache_q, padded, plen, slot,
                                        frames=frames)
        toks = jnp.asarray([[5], [9]], jnp.int32)
        for _ in range(6):
            lg_n, cache_n = native.decode(cache_n, toks)
            lg_q, cache_q = quant.decode(cache_q, toks)
            spread = float(jnp.max(lg_n) - jnp.min(lg_n))
            err = float(jnp.max(jnp.abs(lg_n - lg_q)))
            assert err <= 0.05 * spread, \
                f"{fam}: int8 logit error {err} vs spread {spread}"
            assert (jnp.argmax(lg_n[:, 0], -1)
                    == jnp.argmax(lg_q[:, 0], -1)).all(), fam
            toks = jnp.argmax(lg_n[:, -1:], -1).astype(jnp.int32)


def test_whisper_cross_kv_is_per_slot():
    """Different encoder frames in different slots must produce different
    streams — the cross-KV really is computed per request at admission."""
    cfg, params, reqs = _family_setup("whisper", seed=5)
    backend = eng.make_backend(cfg, params)
    base = reqs[0]
    rng = np.random.default_rng(6)
    other = tuple(tuple(float(x) for x in row) for row in
                  rng.normal(0, 0.5, (cfg.encoder_frames, cfg.d_model)))
    variant = dataclasses.replace(base, rid=99, frames=other)
    ecfg = eng.EngineConfig(n_slots=2, max_len=64)
    out, _, _ = eng.ServingEngine(backend, ecfg).run([base, variant])
    assert out[base.rid] != out[variant.rid]


def test_sample_tokens_bit_identical_to_scalar():
    """The batched sampler (one device call per decode step) must be
    bit-identical to the per-slot sample_token path it replaced."""
    rng = np.random.default_rng(0)
    n, V = 6, 48
    keys = np.stack([np.asarray(jax.random.fold_in(jax.random.PRNGKey(7), i))
                     for i in range(n)])
    fn = jax.jit(lambda lg, t, k, ks, c: eng.sample_tokens(
        lg, t, k, jax.vmap(jax.random.fold_in)(ks, c)))
    for trial in range(10):
        logits = jnp.asarray(rng.normal(0, 2, (n, V)), jnp.float32)
        temps = (rng.uniform(0, 4, n) * (rng.random(n) > 0.3)
                 ).astype(np.float32)
        topks = rng.integers(0, V + 1, n).astype(np.int32)
        counts = rng.integers(0, 50, n).astype(np.int32)
        scalar = [eng.sample_token(
            logits[i], float(temps[i]), int(topks[i]),
            jax.random.fold_in(keys[i], int(counts[i]))) for i in range(n)]
        batched = list(np.asarray(fn(logits, temps, topks, keys, counts)))
        assert scalar == batched, (trial, scalar, batched)


# ---------------------------------------------------------------------------
# traffic simulator properties
# ---------------------------------------------------------------------------

def test_traffic_is_deterministic_and_sorted():
    cfg = traffic.TrafficConfig(n_requests=50, seed=7)
    a, b = traffic.generate(cfg), traffic.generate(cfg)
    assert a == b
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    for r in a:
        assert cfg.prompt_min <= len(r.prompt) <= cfg.prompt_max
        assert cfg.new_tokens_min <= r.max_new_tokens <= cfg.new_tokens_max
        assert all(3 <= t < cfg.vocab_size for t in r.prompt)


def test_traffic_user_popularity_is_zipfian():
    reqs = traffic.generate(traffic.TrafficConfig(n_requests=200, seed=0))
    top = Counter(r.user_id for r in reqs).most_common(1)[0][1]
    # uniform over 10k users would make repeats vanishingly rare
    assert top >= 10


def test_traffic_same_user_shares_history_prefix():
    reqs = traffic.generate(traffic.TrafficConfig(n_requests=200, seed=0))
    by_user = {}
    for r in reqs:
        by_user.setdefault(r.user_id, []).append(r.prompt)
    multi = [ps for ps in by_user.values() if len(ps) >= 2]
    assert multi, "zipf workload should revisit users"
    for ps in multi[:5]:
        a, b = ps[0], ps[1]
        n = min(len(a), len(b)) // 2
        assert n == 0 or a[:n] == b[:n]


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 1000))
def test_bursty_arrivals_are_burstier_than_poisson(seed):
    kw = dict(n_requests=150, rate=50.0, seed=seed)
    gaps = {}
    for proc in ("poisson", "bursty"):
        arr = [r.arrival for r in traffic.generate(
            traffic.TrafficConfig(process=proc, **kw))]
        g = np.diff(np.concatenate([[0.0], arr]))
        gaps[proc] = g.std() / g.mean()        # coefficient of variation
    assert gaps["bursty"] > gaps["poisson"]


def test_slo_tiers_assigned_by_fraction():
    reqs = traffic.generate(traffic.TrafficConfig(
        n_requests=300, interactive_fraction=0.75, seed=0))
    frac = sum(r.slo.name == "interactive" for r in reqs) / len(reqs)
    assert 0.6 < frac < 0.9


# ---------------------------------------------------------------------------
# mrope decode positions (qwen2-vl through the engine)
# ---------------------------------------------------------------------------

def _mrope_reference(cfg, params, prompt, grid, new_tokens):
    """Teacher-forced oracle: re-run the full forward each step with the
    exact text+patch mrope layout and take the last-position argmax."""
    ctx = tf.ModelCtx(attn_chunk=8)
    toks = list(prompt)
    out = []
    for _ in range(new_tokens):
        b = {"tokens": jnp.asarray([toks], jnp.int32),
             "positions": tf.mrope_prompt_positions(cfg, len(toks), grid)}
        logits, _, _ = tf.forward(cfg, params, b, ctx)
        out.append(int(jnp.argmax(logits[0, -1])))
        toks.append(out[-1])
    return out


@pytest.mark.parametrize("grid,kv", [(None, "native"), ((2, 3), "native"),
                                     ((2, 3), "int8")])
def test_qwen2_vl_engine_matches_mrope_reference(grid, kv):
    """Decode positions advance per generated token from the request's
    prefill text+patch layout — engine output must equal the teacher-
    forced full-forward reference (greedy), incl. under int8 KV (which
    routes through the generic Int8KVSlots composition for mrope)."""
    cfg = dataclasses.replace(reduced(get_arch("qwen2-vl-2b")),
                              dtype="float32")
    assert cfg.pos_type == "mrope"
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = tuple(int(x) for x in rng.integers(3, 200, 10))
    backend = eng.make_backend(
        cfg, params,
        layout=CacheLayout(kv_bits=8) if kv == "int8" else None)
    assert backend.needs_positions
    engine = eng.ServingEngine(backend, eng.EngineConfig(n_slots=2,
                                                         max_len=64),
                               clock=traffic.Clock(0.0, 0.0))
    req = traffic.Request(rid=0, user_id=0, prompt=prompt,
                          max_new_tokens=6, arrival=0.0, grid=grid)
    outputs, _, _ = engine.run([req])
    assert outputs[0] == _mrope_reference(cfg, params, prompt, grid, 6)


def test_qwen2_vl_concurrent_grids_keep_per_slot_positions():
    """Two concurrent requests with different patch grids decode with
    their own position streams (slot state cannot leak)."""
    cfg = dataclasses.replace(reduced(get_arch("qwen2-vl-2b")),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    reqs = []
    grids = [None, (2, 2)]
    for i, grid in enumerate(grids):
        prompt = tuple(int(x) for x in rng.integers(3, 200, 8))
        reqs.append(traffic.Request(rid=i, user_id=i, prompt=prompt,
                                    max_new_tokens=5, arrival=0.0,
                                    grid=grid))
    backend = eng.make_backend(cfg, params)
    engine = eng.ServingEngine(backend, eng.EngineConfig(n_slots=2,
                                                         max_len=64),
                               clock=traffic.Clock(0.0, 0.0))
    outputs, _, summary = engine.run(reqs)
    assert summary["finished"] == 2
    for req, grid in zip(reqs, grids):
        assert outputs[req.rid] == _mrope_reference(
            cfg, params, req.prompt, grid, 5), req.rid


def test_traffic_attaches_image_grids():
    reqs = traffic.generate(traffic.TrafficConfig(
        n_requests=40, image_grid=(2, 3), image_fraction=0.5,
        prompt_min=8, prompt_max=24, seed=0))
    with_img = [r for r in reqs if r.grid is not None]
    assert 0 < len(with_img) < len(reqs)
    assert all(r.grid == (2, 3) for r in with_img)
    assert all(len(r.prompt) > 6 for r in with_img)


# ---------------------------------------------------------------------------
# decode hot path: flash-decode impl through the engine, host overhead
# ---------------------------------------------------------------------------

def test_engine_flash_decode_token_exact_vs_dense():
    """The Pallas flash-decode impl is token-exact against the dense path
    through the full continuous-batching engine (uniform family)."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _real_requests(cfg, n=6)
    ecfg = eng.EngineConfig(n_slots=3, max_len=64)
    clock = lambda: traffic.Clock(0.0, 0.0)  # noqa: E731 — deterministic
    dense, _, _ = eng.ServingEngine(
        eng.make_backend(cfg, params), ecfg, clock()).run(reqs)
    flash, _, s = eng.ServingEngine(
        eng.make_backend(cfg, params, layout=CacheLayout(impl="flash")),
        ecfg, clock()).run(reqs)
    assert s["finished"] == len(reqs)
    assert flash == dense


def test_engine_gemma_ring_wraparound_flash_regression():
    """Gemma ring-buffer regression through the engine: generations run the
    local-layer rings far past the sliding window, and the flash-decode
    kernel's wraparound masking must keep every greedy stream identical to
    the dense path."""
    cfg = dataclasses.replace(reduced(get_arch("gemma3-1b")),
                              dtype="float32")
    assert cfg.sliding_window == 8
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    reqs = []
    for i in range(4):
        plen = int(rng.integers(10, 16))     # prompt alone wraps the ring
        reqs.append(traffic.Request(
            rid=i, user_id=i,
            prompt=tuple(int(t) for t in
                         rng.integers(3, cfg.vocab_size, plen)),
            max_new_tokens=12, arrival=0.0))
    ecfg = eng.EngineConfig(n_slots=2, max_len=48)
    dense, _, _ = eng.ServingEngine(
        eng.make_backend(cfg, params), ecfg, traffic.Clock(0.0, 0.0)).run(reqs)
    flash, _, s = eng.ServingEngine(
        eng.make_backend(cfg, params, layout=CacheLayout(impl="flash")),
        ecfg, traffic.Clock(0.0, 0.0)).run(reqs)
    assert s["finished"] == len(reqs)
    assert flash == dense
    # the streams really ran past the window (wraparound exercised)
    assert any(len(reqs[i].prompt) + len(flash[i]) > 2 * cfg.sliding_window
               for i in range(len(reqs)))


def test_engine_no_per_step_recompiles():
    """Host-overhead regression: one decode compile for the whole run (the
    decode signature never changes step to step — the device-resident
    token buffer and donated cache keep it stable) and at most one prefill
    compile per distinct prompt bucket."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _real_requests(cfg, n=8, seed=3)
    backend = eng.make_backend(cfg, params)
    ecfg = eng.EngineConfig(n_slots=3, max_len=64, prompt_quantum=8)
    engine = eng.ServingEngine(backend, ecfg, traffic.Clock(0.0, 0.0))
    _, _, summary = engine.run(reqs)
    assert summary["decode_steps"] > 5
    assert backend._decode._cache_size() == 1, "decode recompiled mid-run"
    buckets = {eng._bucket(len(r.prompt), ecfg.prompt_quantum,
                           ecfg.max_len) for r in reqs}
    assert backend._prefill._cache_size() <= len(buckets)


def test_engine_device_resident_tokens_skip_reupload():
    """On pure decode steps the engine feeds the sampler's device output
    straight back in; the host token array is only re-uploaded after a
    prefill writes a slot."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    req = _real_requests(cfg, n=1)[0]
    backend = eng.make_backend(cfg, params)
    engine = eng.ServingEngine(backend, eng.EngineConfig(n_slots=2,
                                                         max_len=64),
                               traffic.Clock(0.0, 0.0))
    engine.submit(req)
    engine._refill()
    assert engine._tokens_dirty                 # prefill marked it dirty
    engine._decode_once()
    assert not engine._tokens_dirty
    dev_before = engine._tokens_dev
    engine._decode_once()
    assert engine._tokens_dev is not dev_before  # sampler output, no upload
    assert not engine._tokens_dirty
    # device twin always matches the host bookkeeping for live slots
    np.testing.assert_array_equal(
        np.asarray(engine._tokens_dev)[0], engine.slot_tokens[0])


# ---------------------------------------------------------------------------
# chunked / streaming prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_whole_prompt():
    """Streaming prefill (fixed chunks through the decode cache-append
    path) matches the monolithic whole-prompt forward: same last-position
    logits, same cached K/V rows, same decode continuation."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    ctx = tf.ModelCtx(attn_chunk=8)
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (1, 24)), jnp.int32)
    true_len, slot = 13, 1
    base = tf.init_slots(cfg, 3, 64)
    lw, cw = tf.prefill_into_slot(cfg, params, dict(base), toks,
                                  jnp.int32(true_len), jnp.int32(slot), ctx)
    for chunk in (8, 7, 24):
        lc, cc = tf.prefill_into_slot(cfg, params, dict(base), toks,
                                      jnp.int32(true_len), jnp.int32(slot),
                                      ctx, chunk=chunk)
        np.testing.assert_allclose(np.asarray(lc), np.asarray(lw),
                                   atol=2e-5, rtol=2e-5, err_msg=str(chunk))
        np.testing.assert_allclose(
            np.asarray(cc["k"][:, slot, :true_len]),
            np.asarray(cw["k"][:, slot, :true_len]), atol=2e-5, rtol=2e-5)
        assert int(cc["len"][slot]) == true_len
        t = jnp.asarray([[3], [5], [7]], jnp.int32)
        l1, _ = tf.decode_step(cfg, params, cw, t, ctx)
        l2, _ = tf.decode_step(cfg, params, cc, t, ctx)
        np.testing.assert_allclose(np.asarray(l2[slot]), np.asarray(l1[slot]),
                                   atol=2e-5, rtol=2e-5)


def test_chunked_prefill_overhang_does_not_clamp_into_live_rows():
    """Regression: a prompt bucketed to the full cache width with a
    non-dividing chunk pads past S_max; the tail chunk must spill into
    working-row headroom, not clamp back onto (and corrupt) live rows."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    ctx = tf.ModelCtx(attn_chunk=8)
    rng = np.random.default_rng(9)
    s_max, true_len, chunk = 32, 30, 7        # S_pad=35 > S_max
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (1, s_max)),
                       jnp.int32)
    base = tf.init_slots(cfg, 2, s_max)
    lw, cw = tf.prefill_into_slot(cfg, params, dict(base), toks,
                                  jnp.int32(true_len), jnp.int32(0), ctx)
    lc, cc = tf.prefill_into_slot(cfg, params, dict(base), toks,
                                  jnp.int32(true_len), jnp.int32(0), ctx,
                                  chunk=chunk)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lw),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(cc["k"][:, 0, :true_len]),
                               np.asarray(cw["k"][:, 0, :true_len]),
                               atol=2e-5, rtol=2e-5)


def test_engine_chunked_prefill_token_exact():
    """--prefill-chunk end-to-end: the engine's greedy streams are
    unchanged by streaming prefill, composed with int8 KV (which routes
    through the Int8KVSlots composition when chunking)."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _real_requests(cfg, n=5, seed=11)
    ecfg = eng.EngineConfig(n_slots=2, max_len=64)
    whole, _, _ = eng.ServingEngine(
        eng.make_backend(cfg, params), ecfg, traffic.Clock(0.0, 0.0)).run(reqs)
    chunked, _, s = eng.ServingEngine(
        eng.make_backend(cfg, params, prefill_chunk=8), ecfg,
        traffic.Clock(0.0, 0.0)).run(reqs)
    assert s["finished"] == len(reqs)
    assert chunked == whole
    b = eng.make_backend(cfg, params, prefill_chunk=8,
                         layout=CacheLayout(kv_bits=8))
    assert isinstance(b, eng.Int8KVSlots)       # fused path can't chunk
    out_i8, _, s8 = eng.ServingEngine(b, ecfg,
                                      traffic.Clock(0.0, 0.0)).run(reqs)
    assert s8["finished"] == len(reqs)


# ---------------------------------------------------------------------------
# speculative multi-token decode (spec_k > 1)
# ---------------------------------------------------------------------------

class SpecToyBackend(CountingBackend):
    """Deterministic toy with a speculative path: next token = fn(last).
    ``decode_spec`` verifies draft rows with the greedy-accept rule the
    real k-row kernel implements, so the engine's variable-accept commit
    logic is exercised with fully predictable accept patterns."""

    def __init__(self, next_fn=None):
        self.next_fn = next_fn or (lambda t: (t + 1) % self.V)

    def prefill(self, cache, tokens, true_len, slot):
        logits = np.zeros(self.V, np.float32)
        logits[self.next_fn(int(tokens[0, true_len - 1]))] = 1.0
        return logits, cache

    def decode(self, cache, tokens):
        B = tokens.shape[0]
        logits = np.zeros((B, 1, self.V), np.float32)
        for b in range(B):
            logits[b, 0, self.next_fn(int(tokens[b, 0]))] = 1.0
        return logits, cache

    def decode_spec(self, cache, tokens, q_lens, positions=None):
        toks = np.asarray(tokens)
        ql = np.asarray(q_lens)
        B, k = toks.shape
        logits = np.zeros((B, k, self.V), np.float32)
        for b in range(B):
            for j in range(k):
                logits[b, j, self.next_fn(int(toks[b, j]))] = 1.0
        g = logits.argmax(-1)
        accepts = np.ones(B, np.int64)
        for b in range(B):
            while accepts[b] < ql[b] and \
                    toks[b, accepts[b]] == g[b, accepts[b] - 1]:
                accepts[b] += 1
        return logits, accepts, cache


def test_spec_toy_streams_match_single_step():
    """The variable-accept scheduler emits exactly the single-step streams
    on a mixed toy workload (EOS + budget finishes, continuous refill) and
    never leaks a slot."""
    reqs = _toy_workload(n=24, eos_id=5)
    engine = eng.ServingEngine(
        CountingBackend(), eng.EngineConfig(n_slots=3, max_len=64),
        traffic.Clock(0.0, 0.0))
    base, _, s_base = engine.run(reqs)
    spec_eng = eng.ServingEngine(
        SpecToyBackend(), eng.EngineConfig(n_slots=3, max_len=64, spec_k=4),
        traffic.Clock(0.0, 0.0))
    spec, _, s_spec = spec_eng.run(reqs)
    assert spec == base
    assert s_spec["finished"] == s_base["finished"]
    assert not spec_eng.queue
    assert all(r is None for r in spec_eng.slot_req)
    assert s_spec["spec"]["k"] == 4
    assert s_spec["spec"]["accepted_tokens_per_step"] >= 1.0


def test_spec_eos_mid_draft_truncates_the_accept():
    """EOS landing inside an accepted span ends the request at the EOS
    token — the over-committed rows behind it are discarded with the
    slot."""
    a, b, e = 1, 2, 3
    nxt = {a: b, b: e, e: a}
    backend = SpecToyBackend(lambda t: nxt.get(t, 0))
    req = traffic.Request(rid=0, user_id=0, prompt=(a, b, e, a),
                          max_new_tokens=10, arrival=0.0, eos_id=e)
    outs, _, summary = eng.ServingEngine(
        backend, eng.EngineConfig(n_slots=1, max_len=64, spec_k=4),
        traffic.Clock(0.0, 0.0)).run([req])
    # prefill emits b, then one spec step accepts [e, a, b, e] but the
    # stream must stop at the first EOS
    assert outs[0] == [b, e]
    assert summary["finished"] == 1


def test_spec_budget_cap_never_overshoots():
    """A fully-accepting drafter (constant-token model) must still emit
    exactly max_new_tokens — the draft length is capped by the remaining
    budget."""
    backend = SpecToyBackend(lambda t: 7)
    for budget in (1, 2, 3, 5, 8):
        req = traffic.Request(rid=0, user_id=0, prompt=(7, 7, 7),
                              max_new_tokens=budget, arrival=0.0)
        outs, _, _ = eng.ServingEngine(
            backend, eng.EngineConfig(n_slots=1, max_len=64, spec_k=4),
            traffic.Clock(0.0, 0.0)).run([req])
        assert outs[0] == [7] * budget, f"budget {budget}: {outs[0]}"


def test_spec_sampled_slots_fall_back_to_single_token():
    """temperature > 0 slots draft nothing (q_len 1) and keep the exact
    sampled stream of the single-step engine (same per-request keys, same
    fold counts)."""
    reqs = [dataclasses.replace(r, temperature=0.8, top_k=5)
            for r in _toy_workload(n=8)]
    base, _, _ = eng.ServingEngine(
        CountingBackend(), eng.EngineConfig(n_slots=2, max_len=64),
        traffic.Clock(0.0, 0.0)).run(reqs)
    spec, _, summary = eng.ServingEngine(
        SpecToyBackend(), eng.EngineConfig(n_slots=2, max_len=64, spec_k=4),
        traffic.Clock(0.0, 0.0)).run(reqs)
    assert spec == base
    assert summary["spec"]["accepted_tokens_per_step"] == 1.0


def test_ngram_draft_lookup():
    # bigram continuation from the most recent earlier occurrence
    assert eng.ngram_draft([1, 2, 3, 9, 1, 2], 3) == [3, 9, 1]
    # unigram fallback when no bigram recurs
    assert eng.ngram_draft([5, 6, 7, 6], 2) == [7, 6]
    # nothing recurs -> no draft; short/empty histories -> no draft
    assert eng.ngram_draft([1, 2, 3, 4], 3) == []
    assert eng.ngram_draft([1], 3) == []
    assert eng.ngram_draft([1, 2, 3], 0) == []


def _zipf_requests(cfg, n=4, seed=0, max_new=10):
    """Zipfian prompts (recsys-style repetitive ids): the n-gram drafter
    finds real matches, so accepts exercise the >1 path."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(6, 14))
        toks = np.minimum(rng.zipf(1.2, plen) + 2, cfg.vocab_size - 1)
        reqs.append(traffic.Request(
            rid=i, user_id=i, prompt=tuple(int(t) for t in toks),
            max_new_tokens=max_new, arrival=0.0))
    return reqs


SPEC_LAYOUTS = [CacheLayout(), CacheLayout(kind="paged"),
                CacheLayout(kv_bits=8), CacheLayout(kind="paged", kv_bits=8)]


@pytest.mark.parametrize("layout", SPEC_LAYOUTS,
                         ids=["dense", "paged", "int8", "paged_int8"])
def test_spec_decode_token_exact_uniform_layout_matrix(layout):
    """spec_k=4 greedy streams are token-identical to single-step decode
    for the uniform family across the full (dense|paged) x (bf16|int8)
    layout matrix, with real multi-token accepts."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _zipf_requests(cfg)
    explicit = layout != CacheLayout()
    backend = eng.make_backend(cfg, params,
                               layout=layout if explicit else None)
    e_spec = eng.ServingEngine(backend, eng.EngineConfig(
        n_slots=3, max_len=64, spec_k=4, layout=layout))
    spec, _, s_spec = e_spec.run(reqs)
    base, _, s_base = eng.ServingEngine(backend, eng.EngineConfig(
        n_slots=3, max_len=64, layout=layout)).run(reqs)
    for r in reqs:
        assert spec[r.rid] == base[r.rid], f"request {r.rid} diverged"
    assert s_spec["spec"]["accepted_tokens_per_step"] >= 1.0
    assert s_spec["decode_steps"] <= s_base["decode_steps"]
    if layout.paged:
        # rejected draft rows over-secure blocks past the committed
        # frontier; retirement must still drain every refcount
        assert e_spec.pool.used_blocks == 0


@pytest.mark.parametrize("fam,layout", [
    ("gemma", CacheLayout()), ("gemma", CacheLayout(kind="paged")),
    ("whisper", CacheLayout())],
    ids=["gemma_dense", "gemma_paged", "whisper_dense"])
def test_spec_decode_token_exact_gemma_whisper(fam, layout):
    """Gemma ring buffers (spec-margined: window + k - 1 rows, exercised
    past the wraparound) and whisper cross-KV keep speculative streams
    identical to single-step.  The baseline shares the backend, so both
    engines run the same margined ring layout — bit-identical logits."""
    cfg, params, reqs = _family_setup(fam)
    reqs = [dataclasses.replace(r, max_new_tokens=12) for r in reqs]
    explicit = layout != CacheLayout()
    backend = eng.make_backend(cfg, params,
                               layout=layout if explicit else None)
    spec, _, s_spec = eng.ServingEngine(backend, eng.EngineConfig(
        n_slots=3, max_len=64, spec_k=4, layout=layout)).run(reqs)
    base, _, _ = eng.ServingEngine(backend, eng.EngineConfig(
        n_slots=3, max_len=64, layout=layout)).run(reqs)
    for r in reqs:
        assert spec[r.rid] == base[r.rid], f"{fam} request {r.rid} diverged"
    assert s_spec["spec"]["accepted_tokens_per_step"] >= 1.0


@pytest.mark.parametrize("fam", ["jamba", "rwkv6"])
def test_spec_decode_rejects_recurrent_families(fam):
    cfg = dataclasses.replace(reduced(get_arch(FAMILY_ARCHS[fam])),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    backend = eng.make_backend(cfg, params)
    with pytest.raises(ValueError, match="recurrent"):
        eng.ServingEngine(backend, eng.EngineConfig(spec_k=2))
