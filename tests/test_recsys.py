"""Recommendation workload: synthetic dataset statistics, metrics, and a
tiny end-to-end training sanity check (HR@10 beats random after training)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, reduced
from repro.models.transformer import ModelCtx
from repro.recsys import dataset, metrics, model as recmodel


def test_dataset_statistics():
    ds = dataset.generate(scale=0.01, seed=0)
    assert ds.n_users >= 32 and ds.n_items >= 64
    n = len(ds.user)
    b1, b2 = ds.split
    assert abs(b1 / n - 0.8) < 0.01 and abs(b2 / n - 0.9) < 0.01
    # chronological split
    assert ds.time[:b1].max() <= ds.time[b1:].min() + 1
    # popularity is long-tailed: top 10% of items get >3x the uniform share
    # (zipf base diluted by the 60% user-taste clustering component)
    counts = np.bincount(ds.item, minlength=ds.n_items)
    top = np.sort(counts)[::-1]
    assert top[: ds.n_items // 10].sum() > 0.3 * counts.sum()


def test_seq_batches_shapes():
    ds = dataset.generate(scale=0.01, seed=0)
    it = dataset.seq_batches(ds, batch=8, seq_len=16, steps=3)
    for b in it:
        assert b["tokens"].shape == (8, 16)
        assert b["targets"].shape == (8, 16)
        assert (b["tokens"] >= 0).all()
        # targets align: targets[t] == tokens[t+1] where both valid
        np.testing.assert_array_equal(b["tokens"][:, 1:][b["targets"][:, :-1] > 0],
                                      b["targets"][:, :-1][b["targets"][:, :-1] > 0])


def test_hr_ndcg_known_ranking():
    scores = jnp.asarray([[0.1, 0.9, 0.5, 0.2],
                          [0.9, 0.1, 0.2, 0.3]])
    gold = jnp.asarray([1, 1])       # item 1: rank 0 for user0, rank 3 user1
    hr, ndcg = metrics.hr_ndcg_at_k(scores, gold, k=2)
    assert float(hr) == 0.5
    np.testing.assert_allclose(float(ndcg), 0.5 * (1.0 / np.log2(2)), atol=1e-6)


def test_history_exclusion():
    toks = np.array([[3, 4, 0], [5, 5, 0]])
    m = metrics.history_exclusion(toks, 8)
    assert m[0, 3] and m[0, 4] and not m[0, 5]
    assert m[1, 5] and not m[1, 3]
    assert m[:, :3].all()            # specials always excluded


def test_score_users_clamps_full_window_lens():
    """Regression: ``lens == S`` (a full history window) must read the
    last position's logits, not index one past the sequence."""
    ds = dataset.generate(scale=0.005, seed=0)
    cfg = dataclasses.replace(
        reduced(get_arch("recllm-base")), vocab_size=ds.n_items + 3,
        vocab_pad_to=32, dtype="float32")
    ctx = ModelCtx(attn_chunk=8)
    params = recmodel.init_recllm(jax.random.PRNGKey(0), cfg, ds.n_users)
    S = 16
    toks = jnp.asarray(np.random.default_rng(0).integers(3, ds.n_items + 3,
                                                         (4, S)), jnp.int32)
    users = jnp.zeros((4,), jnp.int32)
    at_cap = recmodel.score_users(cfg, params, toks, users,
                                  jnp.full((4,), S, jnp.int32), ctx)
    at_last = recmodel.score_users(cfg, params, toks, users,
                                   jnp.full((4,), S - 1, jnp.int32), ctx)
    np.testing.assert_array_equal(np.asarray(at_cap), np.asarray(at_last))
    # in-range lens are untouched by the clamp
    mid = recmodel.score_users(cfg, params, toks, users,
                               jnp.full((4,), 3, jnp.int32), ctx)
    assert not np.array_equal(np.asarray(mid), np.asarray(at_last))


@pytest.mark.slow
def test_recllm_training_beats_random():
    ds = dataset.generate(scale=0.005, seed=0)
    cfg = dataclasses.replace(
        reduced(get_arch("recllm-base")), vocab_size=ds.n_items + 3,
        vocab_pad_to=32, dtype="float32")
    ctx = ModelCtx(attn_chunk=8)
    params = recmodel.init_recllm(jax.random.PRNGKey(0), cfg, ds.n_users)

    toks, gold, lens = dataset.eval_examples(ds, seq_len=16, max_users=128)
    users = jnp.zeros((toks.shape[0],), jnp.int32)

    def eval_hr(p):
        scores = recmodel.score_users(cfg, p, jnp.asarray(toks), users,
                                      jnp.asarray(lens), ctx)
        return metrics.hr_ndcg_at_k(scores, jnp.asarray(gold), k=10)

    hr0, _ = eval_hr(params)

    loss_fn = lambda p, b: recmodel.recllm_loss(cfg, p, b, ctx)[0]  # noqa
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def sgd(p, g):
        return jax.tree.map(lambda a, b: a - 0.5 * b, p, g)

    losses = []
    for i, batch in enumerate(dataset.seq_batches(ds, 16, 16, steps=60)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, g = grad_fn(params, batch)
        params = sgd(params, g)
        losses.append(float(loss))
    hr1, ndcg1 = eval_hr(params)
    assert losses[-1] < losses[0]
    random_hr = 10 / (ds.n_items + 3)
    assert float(hr1) > max(2 * random_hr, float(hr0) * 0.9)
