"""Data pipeline + tokenizer tests."""
import jax.numpy as jnp
import numpy as np

from repro.data import pipeline, tokenizer


def test_synthetic_lm_batches_deterministic():
    a = list(pipeline.synthetic_lm_batches(100, 4, 8, 3, seed=1))
    b = list(pipeline.synthetic_lm_batches(100, 4, 8, 3, seed=1))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert a[0]["tokens"].shape == (4, 8)
    assert a[0]["tokens"].max() < 100
    # next-token alignment
    np.testing.assert_array_equal(a[0]["tokens"][:, 1:], a[0]["targets"][:, :-1])


def test_prefetcher_yields_all():
    src = pipeline.synthetic_lm_batches(50, 2, 4, 5, seed=0)
    got = list(pipeline.Prefetcher(src, size=2))
    assert len(got) == 5
    assert isinstance(got[0]["tokens"], jnp.ndarray)


def test_hash_tokenizer():
    tok = tokenizer.HashTokenizer(1000)
    ids = tok.encode("hello world hello", max_len=8)
    assert len(ids) == 8
    assert ids[0] == 1                       # bos
    assert ids[1] == ids[3]                  # same word same id
    assert all(0 <= i < 1000 for i in ids)
    ids2 = tok.encode("hello world hello", max_len=8)
    assert ids == ids2                       # deterministic
