"""Multi-device integration tests: run ``distributed_checks.py`` once in a
subprocess with 8 host devices and assert each check's result."""
import json
import os
import subprocess
import sys

import pytest

_RESULTS = None


def results():
    global _RESULTS
    if _RESULTS is None:
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        p = subprocess.run(
            [sys.executable, os.path.join(os.path.dirname(__file__),
                                          "distributed_checks.py")],
            capture_output=True, text=True, timeout=1200, env=env)
        for line in p.stdout.splitlines():
            if line.startswith("RESULTS_JSON:"):
                _RESULTS = json.loads(line[len("RESULTS_JSON:"):])
                break
        else:
            raise RuntimeError(
                f"no results marker; rc={p.returncode}\n"
                f"stdout:\n{p.stdout[-2000:]}\nstderr:\n{p.stderr[-3000:]}")
    return _RESULTS


CHECKS = [
    "hierarchical_allreduce_equals_flat",
    "onebit_sync_matches_manual",
    "topk_sync_matches_manual",
    "gpipe_matches_serial",
    "pipeline_1f1b_matches_gpipe_and_serial",
    "pp_hybrid_train_step_matches_dp",
    "pp_train_step_compressed_embed_sync_converges",
    "pp_rebalance_in_loop",
    "pp_launch_train_e2e",
    "embed_zero_opt_state_matches_replicated",
    "dp_train_step_hier_and_compressed_converge",
    "hybrid_gspmd_train_step_runs",
    "elastic_reshard_roundtrip",
    "embed_sharded_lookup_matches_replicated",
    "embed_sparse_row_sync_matches_dense_pmean",
    "dp_train_step_sparse_embed_matches_dense",
    "hybrid_recllm_embed_plan_matches_replicated",
    "cf_hot_row_cache_matches_sharded",
    "dryrun_cell_on_host_mesh",
]


@pytest.mark.parametrize("name", CHECKS)
def test_distributed_check(name):
    r = results()
    assert name in r, f"check {name} never ran"
    assert r[name]["ok"], f"{name}: {r[name].get('error')}\n" \
                          f"{r[name].get('tb', '')}"


def test_compressed_dp_converges_like_flat():
    r = results()
    losses = r.get("dp_losses", {})
    if not losses:
        pytest.skip("dp step check failed upstream")
    # compressed modes converge (within 10x of exact sync / below an
    # absolute floor well under the initial ~14.0)
    flat_final = losses["flat"][1]
    for mode in ("onebit", "topk", "hierarchical"):
        assert losses[mode][1] < max(10 * flat_final, 3.0), (mode, losses)
