"""Unit tests for the paper-core algorithms that need no multi-device mesh:
load balancing (C4), async delay compensation (C7), the hybrid planner (C8),
and straggler mitigation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import compat
from repro.config import SHAPES, ParallelConfig, get_arch
from repro.core import async_dp, hybrid, load_balance as lb
from repro.runtime import straggler


# -- expert rebalancing (LPT) -------------------------------------------------

def test_rebalance_experts_improves_balance():
    rng = np.random.default_rng(0)
    load = rng.pareto(1.5, 64) + 0.1
    assign, perm = lb.rebalance_experts(load, 8)
    q = lb.balance_quality(load, assign, 8)
    naive = lb.balance_quality(load, np.arange(64) // 8, 8)
    lower = load.max() / (load.sum() / 8)
    assert q <= naive
    assert q <= max(1.0, lower) * 1.2
    # capacity respected, permutation valid
    assert (np.bincount(assign, minlength=8) == 8).all()
    assert sorted(perm) == list(range(64))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8, 16]))
def test_rebalance_property(seed, n_dev):
    rng = np.random.default_rng(seed)
    E = n_dev * rng.integers(1, 9)
    load = rng.exponential(1.0, E) + 1e-3
    assign, perm = lb.rebalance_experts(load, n_dev)
    assert (np.bincount(assign, minlength=n_dev) == E // n_dev).all()
    naive = lb.balance_quality(load, np.arange(E) % n_dev, n_dev)
    assert lb.balance_quality(load, assign, n_dev) <= naive + 1e-9


# -- pipeline stage balancing --------------------------------------------------

def test_balance_stages_optimal_on_known_case():
    costs = [1, 1, 1, 1, 10, 1, 1, 1]
    b = lb.balance_stages(costs, 2)
    sc = lb.stage_costs(costs, b)
    # brute-force optimum over all single cuts
    best = min(max(sum(costs[:i]), sum(costs[i:])) for i in range(1, 8))
    assert sc.max() == best == 13.0
    assert b[0] == 0 and b[-1] == 8


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.1, 10), min_size=4, max_size=24),
       st.integers(2, 4))
def test_balance_stages_beats_uniform(costs, n_stages):
    if len(costs) < n_stages:
        return
    b = lb.balance_stages(costs, n_stages)
    opt = lb.stage_costs(costs, b).max()
    L = len(costs)
    uni = [round(i * L / n_stages) for i in range(n_stages + 1)]
    uni_cost = max(sum(costs[uni[s]:uni[s + 1]]) for s in range(n_stages))
    assert opt <= uni_cost + 1e-9
    # contiguity + coverage
    assert b[0] == 0 and b[-1] == L and all(x <= y for x, y in zip(b, b[1:]))


# -- adaptive batch allocation ----------------------------------------------

def test_adaptive_batch_allocation_proportional():
    alloc = lb.adaptive_batch_allocation([1, 1, 2, 4], 256)
    assert alloc.sum() == 256
    assert alloc[3] > alloc[2] > alloc[0]
    # per-worker time is near-equal
    t = alloc / np.array([1, 1, 2, 4])
    assert t.max() / t.min() < 1.2


def test_straggler_dropk():
    w = lb.straggler_dropk_weights([5, 1, 2, 3, 4], drop_k=1)
    assert w[0] == 0.0               # slowest (highest arrival) dropped
    np.testing.assert_allclose(w.sum(), 1.0)


# -- async delay compensation (Eq. 12) ---------------------------------------

def quad_problem(seed=1):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    A = A @ A.T / 8 + jnp.eye(8)

    def loss(p, b):
        return 0.5 * p @ A @ p + b @ p

    stream = [jnp.asarray(rng.normal(size=8) * 0.01, jnp.float32)
              for _ in range(60)]
    return loss, stream


def test_delay_compensation_beats_naive_async():
    loss, stream = quad_problem()
    p0 = jnp.ones(8)
    cfg_c = async_dp.AsyncConfig(max_staleness=6, compensate=True, lr=0.15,
                                 staleness="straggler")
    cfg_n = async_dp.AsyncConfig(max_staleness=6, compensate=False, lr=0.15,
                                 staleness="straggler")
    _, l_comp = async_dp.simulate_async_sgd(loss, p0, stream, cfg_c)
    _, l_naive = async_dp.simulate_async_sgd(loss, p0, stream, cfg_n)
    _, l_sync = async_dp.simulate_sync_sgd(loss, p0, stream, 0.15)
    # paper's qualitative ordering: sync <= compensated < naive
    assert l_comp[-1] < l_naive[-1]
    assert l_sync[-1] <= l_comp[-1] + 1e-3


def test_async_converges_with_zero_staleness():
    loss, stream = quad_problem(2)
    p0 = jnp.ones(8)
    cfg = async_dp.AsyncConfig(max_staleness=0, compensate=True, lr=0.15)
    _, l_async = async_dp.simulate_async_sgd(loss, p0, stream, cfg)
    _, l_sync = async_dp.simulate_sync_sgd(loss, p0, stream, 0.15)
    np.testing.assert_allclose(l_async[-1], l_sync[-1], atol=1e-5)


# -- hybrid planner -----------------------------------------------------------

def test_model_flops_close_to_6nd():
    cfg = get_arch("internlm2-20b")
    f = hybrid.model_flops(cfg, 4096, 256)
    six_nd = 6 * cfg.num_params() * 4096 * 256
    assert 0.9 < f / six_nd < 1.3    # attention quadratic adds ~10%


def test_moe_flops_use_active_params():
    cfg = get_arch("qwen3-moe-30b-a3b")
    f = hybrid.model_flops(cfg, 4096, 256)
    six_nd_active = 6 * cfg.active_params() * 4096 * 256
    six_nd_full = 6 * cfg.num_params() * 4096 * 256
    assert f < 0.5 * six_nd_full
    assert 0.8 < f / six_nd_active < 1.8


def test_auto_plan_remats_training():
    import jax
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    plan = hybrid.auto_plan(get_arch("internlm2-20b"), mesh,
                            SHAPES["train_4k"], ParallelConfig())
    assert plan.remat
    plan_d = hybrid.auto_plan(get_arch("internlm2-20b"), mesh,
                              SHAPES["decode_32k"], ParallelConfig())
    assert not plan_d.remat


# -- straggler simulation ------------------------------------------------------

def test_straggler_policies_ordering():
    sim = straggler.StragglerSim(n_workers=8, hetero_cv=0.4, flaky_prob=0.1)
    out = straggler.compare_policies(sim, global_batch=1024, steps=300)
    # adaptive allocation beats uniform under heterogeneity
    assert out["adaptive"]["throughput"] > out["uniform"]["throughput"]
    # dropk trades useful samples for speed but throughput >= uniform
    assert out["dropk"]["throughput"] > out["uniform"]["throughput"]
    assert out["dropk"]["useful_frac"] < 1.0
