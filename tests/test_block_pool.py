"""Host-side paged-cache accounting: BlockPool refcounts, SlotTables
prefix-sharing admission, copy-on-write, exhaustion behaviour.

Pure Python/numpy — no jax.  The device-facing guarantees (paged kernels,
engine token parity) live in test_decode_kernel.py / test_paged_serving.py.
"""
import numpy as np
import pytest

from repro.cache_layout import (CacheLayout, blocks_per_slot,
                                resolved_num_blocks)
from repro.serving.block_pool import (NULL_BLOCK, BlockPool, SlotTables,
                                      prefix_keys)


# ---------------------------------------------------------------------------
# CacheLayout spec
# ---------------------------------------------------------------------------

def test_cache_layout_validation_and_helpers():
    lay = CacheLayout(kind="paged", block_size=8)
    assert lay.paged and not lay.quantized
    assert blocks_per_slot(lay, 64) == 8
    # +1: block 0 is the reserved null sink
    assert resolved_num_blocks(lay, n_slots=4, max_len=64) == 4 * 8 + 1
    assert resolved_num_blocks(lay.replace(num_blocks=12), 4, 64) == 13
    with pytest.raises(ValueError):
        CacheLayout(kind="pooled")
    with pytest.raises(ValueError):
        CacheLayout(kv_bits=4)
    with pytest.raises(ValueError):
        blocks_per_slot(lay, 60)        # not a block multiple


def test_legacy_shim_is_gone():
    # the PR-6 one-release deprecation window closed: the translation
    # helper is deleted outright
    import repro.cache_layout as cl
    assert not hasattr(cl, "layout_from_legacy")


# ---------------------------------------------------------------------------
# prefix keys: chained content hash
# ---------------------------------------------------------------------------

def test_prefix_keys_chain_and_tail():
    keys_a, tail_a = prefix_keys([1, 2, 3, 4, 5, 6, 7], 4, seed="m")
    keys_b, tail_b = prefix_keys([1, 2, 3, 4, 9, 9, 9], 4, seed="m")
    assert len(keys_a) == len(keys_b) == 1
    assert keys_a[0] == keys_b[0]           # identical first block
    assert tail_a != tail_b                 # divergent partial tails
    # chaining: a different block 0 changes block 1's key too
    keys_c, _ = prefix_keys([9, 2, 3, 4, 5, 6, 7, 8], 4, seed="m")
    keys_d, _ = prefix_keys([1, 2, 3, 4, 5, 6, 7, 8], 4, seed="m")
    assert keys_c[1] != keys_d[1]
    # the namespace seed partitions caches
    assert prefix_keys([1, 2, 3, 4], 4, seed="m")[0] != \
        prefix_keys([1, 2, 3, 4], 4, seed="n")[0]
    # exact block boundary: no tail
    assert prefix_keys([1, 2, 3, 4], 4)[1] is None


# ---------------------------------------------------------------------------
# BlockPool allocator
# ---------------------------------------------------------------------------

def test_pool_alloc_free_refcount_roundtrip():
    pool = BlockPool(num_blocks=5, block_size=4)
    assert pool.free_blocks == 4 and pool.used_blocks == 0
    a, b = pool.alloc(), pool.alloc()
    assert a != NULL_BLOCK and b != NULL_BLOCK and a != b
    pool.incref(a)
    pool.decref(a)
    assert pool.used_blocks == 2            # still referenced
    pool.decref(a)
    pool.decref(b)
    assert pool.used_blocks == 0 and pool.free_blocks == 4
    assert pool.peak_used == 2
    with pytest.raises(RuntimeError):
        pool.decref(a)                      # underflow detected


def test_pool_seal_lookup_and_unseal_on_free():
    pool = BlockPool(num_blocks=4, block_size=4)
    b = pool.alloc()
    pool.seal(b, key=123)
    assert pool.lookup(123) == b and pool.is_sealed(b)
    pool.decref(b)                          # last ref: freed AND unpublished
    assert pool.lookup(123) is None and not pool.is_sealed(b)


# ---------------------------------------------------------------------------
# SlotTables: admission, sharing, COW, exhaustion, release
# ---------------------------------------------------------------------------

def _tables(num_blocks=9, n_slots=3, bpslot=4, bs=4):
    pool = BlockPool(num_blocks, bs)
    return pool, SlotTables(pool, n_slots, bpslot)


def test_admit_owns_then_shares_prefix():
    pool, tables = _tables()
    prompt = [1, 2, 3, 4, 5, 6, 7, 8]       # two complete blocks
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=3)
    # nothing sealed yet: slot 0 owns all three blocks (write == read)
    assert (tables.write[0][:3] == tables.read[0][:3]).all()
    tables.seal_prompt(0)
    assert tables.admit(1, keys, tail, span_blocks=3)
    # the two complete prompt blocks are shared read-only
    assert (tables.read[1][:2] == tables.read[0][:2]).all()
    assert (tables.write[1][:2] == NULL_BLOCK).all()
    assert pool.refcount[tables.read[0][0]] == 2
    assert pool.shared_hits == 2
    # block 2 (first decode block) is private to each slot
    assert tables.read[1][2] != tables.read[0][2]


def test_shared_tail_cow_on_first_divergent_token():
    pool, tables = _tables()
    prompt = [1, 2, 3, 4, 5, 6]             # one full block + 2-token tail
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=2)
    tables.seal_prompt(0)
    assert tables.admit(1, keys, tail, span_blocks=2)
    shared_tail = int(tables.read[1][1])
    assert shared_tail == int(tables.read[0][1])
    assert pool.cow_debt == 1               # one deferred private copy
    # slot 1 writes its first generated token at position 6 -> COW
    cow = tables.ensure_writable(1, 6)
    assert cow is not None
    src, dst = cow
    assert src == shared_tail and dst != shared_tail
    assert int(tables.read[1][1]) == dst
    assert int(tables.write[1][1]) == dst
    assert pool.cow_debt == 0 and pool.cow_events == 1
    # slot 0 is now the sole owner: claims its tail in place, no copy
    assert tables.ensure_writable(0, 6) is None
    assert int(tables.write[0][1]) == int(tables.read[0][1])


def test_exhaustion_admission_fails_without_mutation():
    pool, tables = _tables(num_blocks=4)    # 3 usable blocks
    keys, tail = prefix_keys(list(range(12)), 4)
    assert tables.admit(0, keys, tail, span_blocks=3)
    before = (tables.read.copy(), tables.write.copy(),
              pool.refcount.copy(), pool.cow_debt, pool.free_blocks)
    keys2, tail2 = prefix_keys(list(range(100, 112)), 4)
    assert not tables.admit(1, keys2, tail2, span_blocks=3)
    after = (tables.read, tables.write, pool.refcount, pool.cow_debt,
             pool.free_blocks)
    assert (before[0] == after[0]).all() and (before[1] == after[1]).all()
    assert (before[2] == after[2]).all()
    assert before[3] == after[3] and before[4] == after[4]
    # blocks come back at release; the queued request then fits
    tables.release(0)
    assert tables.admit(1, keys2, tail2, span_blocks=3)


def test_cow_reservation_blocks_unsafe_admission():
    # a shared-tail adoption must hold one block back for its deferred COW:
    # a later admission cannot eat the reserve
    pool, tables = _tables(num_blocks=5, n_slots=3, bpslot=2)
    prompt = [1, 2, 3, 4, 5, 6]
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=2)       # 2 blocks
    tables.seal_prompt(0)
    assert tables.admit(1, keys, tail, span_blocks=2)       # shares both
    assert pool.cow_debt == 1 and pool.free_blocks == 2
    # 2 free - 1 reserved = 1 usable: a 2-block request must wait
    keys2, tail2 = prefix_keys([7, 8, 9, 10, 11], 4)
    assert not tables.admit(2, keys2, tail2, span_blocks=2)
    # ... and the reserved COW then always succeeds
    assert tables.ensure_writable(1, 6) is not None


def test_release_returns_refcounts_to_zero():
    pool, tables = _tables()
    prompt = [1, 2, 3, 4, 5, 6, 7]
    keys, tail = prefix_keys(prompt, 4)
    for s in range(3):
        assert tables.admit(s, keys, tail, span_blocks=3)
        tables.seal_prompt(s)
    for s in range(3):
        tables.ensure_writable(s, 7)        # resolve pending tails
    for s in range(3):
        tables.release(s)
    assert pool.refcount[NULL_BLOCK] == 1   # the permanent null sink
    assert (pool.refcount[1:] == 0).all()
    assert pool.used_blocks == 0 and pool.cow_debt == 0
    assert (tables.read == NULL_BLOCK).all()
    assert (tables.write == NULL_BLOCK).all()


def test_resealed_prefix_is_shared_after_full_drain():
    # sharing survives a drain only via re-seal: blocks free at refcount 0,
    # so a later identical prompt re-admits privately and re-publishes
    pool, tables = _tables()
    keys, tail = prefix_keys([1, 2, 3, 4, 5], 4)
    assert tables.admit(0, keys, tail, 2)
    tables.seal_prompt(0)
    tables.release(0)
    assert pool.lookup(keys[0]) is None     # unpublished with the free
    assert tables.admit(1, keys, tail, 2)
    assert pool.shared_hits == 0            # nothing to share: recomputed
    tables.seal_prompt(1)
    assert tables.admit(2, keys, tail, 2)
    assert pool.shared_hits > 0


# ---------------------------------------------------------------------------
# speculative multi-token spans
# ---------------------------------------------------------------------------

def test_span_costs_one_copy_per_touched_block():
    # slot 1 shares a sealed full block plus the partial tail; a k-token
    # speculative span crossing from the tail into its private decode block
    # triggers exactly one COW copy, regardless of how many tokens land
    pool, tables = _tables()
    prompt = [1, 2, 3, 4, 5, 6]             # one full block + 2-token tail
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=3)
    tables.seal_prompt(0)
    assert tables.admit(1, keys, tail, span_blocks=3)
    shared_tail = int(tables.read[1][1])
    # 6 tokens from position 6 touch virtual blocks 1 (shared tail -> COW)
    # and 2 (already private) -> exactly one (src, dst) pair
    pairs = tables.ensure_writable_span(1, 6, 6)
    assert len(pairs) == 1
    src, dst = pairs[0]
    assert src == shared_tail and dst != shared_tail
    assert int(tables.read[1][1]) == int(tables.write[1][1]) == dst
    assert pool.cow_events == 1 and pool.cow_debt == 0
    # idempotent: re-securing the same range copies nothing
    assert tables.ensure_writable_span(1, 6, 6) == []
    assert pool.cow_events == 1
    # degenerate spans are no-ops
    assert tables.ensure_writable_span(1, 6, 0) == []


def test_span_matches_per_position_ensure_writable():
    # the span call is the batched twin of ensure_writable: securing
    # [start, start+k) must leave the tables exactly where k single-position
    # calls would, with the same COW pairs
    prompt = [1, 2, 3, 4, 5, 6, 7]
    keys, tail = prefix_keys(prompt, 4)

    def run(batched):
        pool, tables = _tables(num_blocks=12, n_slots=2, bpslot=4, bs=4)
        assert tables.admit(0, keys, tail, span_blocks=4)
        tables.seal_prompt(0)
        assert tables.admit(1, keys, tail, span_blocks=4)
        if batched:
            pairs = tables.ensure_writable_span(1, 7, 5)
        else:
            pairs = [p for pos in range(7, 12)
                     if (p := tables.ensure_writable(1, pos)) is not None]
        return pool, tables, pairs

    pool_a, tab_a, pairs_a = run(batched=True)
    pool_b, tab_b, pairs_b = run(batched=False)
    assert pairs_a == pairs_b and len(pairs_a) == 1
    assert (tab_a.read == tab_b.read).all()
    assert (tab_a.write == tab_b.write).all()
    assert pool_a.cow_events == pool_b.cow_events == 1


def test_spec_rejection_drains_refcounts():
    # speculative decode secures a k-token span up front; when verification
    # rejects most of the draft the slot's KV frontier stays behind the
    # secured range.  The over-secured blocks must still retire with the
    # slot -- nothing leaks
    pool, tables = _tables(num_blocks=17, n_slots=2, bpslot=8, bs=4)
    prompt = [1, 2, 3, 4, 5, 6]
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=8)
    tables.seal_prompt(0)
    assert tables.admit(1, keys, tail, span_blocks=8)
    # slot 1 drafts k=4 from position 6 (COWs the tail) but verify accepts
    # only one token; the next step re-secures an overlapping span -> the
    # already-claimed blocks cost nothing
    assert len(tables.ensure_writable_span(1, 6, 4)) == 1
    assert tables.ensure_writable_span(1, 7, 4) == []
    for s in range(2):
        tables.release(s)
    assert pool.refcount[NULL_BLOCK] == 1
    assert (pool.refcount[1:] == 0).all()
    assert pool.used_blocks == 0 and pool.cow_debt == 0


# ---------------------------------------------------------------------------
# handoff: export_slot / import_slot (disaggregated prefill -> decode)
# ---------------------------------------------------------------------------

def test_export_snapshot_is_pure_read():
    pool, tables = _tables()
    prompt = [1, 2, 3, 4, 5, 6, 7]
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=3)
    tables.seal_prompt(0)
    before = (tables.read.copy(), tables.write.copy(),
              pool.refcount.copy(), pool.cow_debt)
    blocks, bkeys = tables.export_slot(0)
    # the allocated span in virtual order, sealed keys where published
    assert len(blocks) == 3 and NULL_BLOCK not in blocks
    assert bkeys[0] == keys[0]
    assert bkeys[1] == tail                 # sealed partial tail
    assert bkeys[2] is None                 # unsealed decode budget
    after = (tables.read, tables.write, pool.refcount, pool.cow_debt)
    assert (before[0] == after[0]).all() and (before[1] == after[1]).all()
    assert (before[2] == after[2]).all() and before[3] == after[3]


def test_cross_pool_handoff_drains_both_pools():
    # prefill tier exports, releases; decode tier imports fresh copies.
    # After the decode side retires, BOTH pools are back to zero refcounts
    src_pool, src = _tables()
    dst_pool, dst = _tables()
    prompt = [1, 2, 3, 4, 5, 6, 7]
    keys, tail = prefix_keys(prompt, 4)
    assert src.admit(0, keys, tail, span_blocks=2)
    src.seal_prompt(0)
    blocks, bkeys = src.export_slot(0)
    src.release(0)
    assert src_pool.used_blocks == 0
    assert (src_pool.refcount[1:] == 0).all()
    copies = dst.import_slot(0, blocks, bkeys, live_tokens=7,
                             src_pool=src_pool, span_blocks=3)
    # nothing matches in the fresh pool: every live block is a copy
    assert copies is not None and len(copies) == 2
    assert [i for i, _ in copies] == [0, 1]
    dst.release(0)
    assert dst_pool.used_blocks == 0 and dst_pool.cow_debt == 0
    assert (dst_pool.refcount[1:] == 0).all()


def test_cross_pool_import_adopts_sealed_prefix():
    # the destination pool already serves the same prompt prefix: the
    # transferred chain dedupes against it by content key -- prefix
    # sharing survives the pool boundary
    src_pool, src = _tables()
    dst_pool, dst = _tables()
    prompt = [1, 2, 3, 4, 5, 6, 7, 8]       # two sealed blocks
    keys, tail = prefix_keys(prompt, 4)
    assert dst.admit(0, keys, tail, span_blocks=3)
    dst.seal_prompt(0)
    resident = [int(dst.read[0][0]), int(dst.read[0][1])]
    assert src.admit(0, keys, tail, span_blocks=2)
    src.seal_prompt(0)
    blocks, bkeys = src.export_slot(0)
    src.release(0)
    copies = dst.import_slot(1, blocks, bkeys, live_tokens=8,
                             src_pool=src_pool, span_blocks=3)
    assert copies == []                     # both live blocks adopted
    assert [int(dst.read[1][0]), int(dst.read[1][1])] == resident
    assert (dst.write[1][:2] == NULL_BLOCK).all()
    assert dst_pool.refcount[resident[0]] == 2
    assert dst_pool.shared_hits == 2
    dst.release(0)
    dst.release(1)
    assert dst_pool.used_blocks == 0
    assert (dst_pool.refcount[1:] == 0).all()


def test_shared_pool_import_rerefcounts_without_copies():
    # tiers over one physical pool: the handoff is O(span) increfs, no
    # value movement at all
    pool, tables = _tables(num_blocks=9, n_slots=3, bpslot=4)
    prompt = [1, 2, 3, 4, 5, 6, 7]
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=2)
    tables.seal_prompt(0)
    blocks, bkeys = tables.export_slot(0)
    copies = tables.import_slot(1, blocks, bkeys, live_tokens=7,
                                src_pool=pool, span_blocks=3)
    assert copies == []
    assert [int(b) for b in tables.read[1][:2]] == blocks
    assert (tables.write[1][:2] == NULL_BLOCK).all()
    assert pool.refcount[blocks[0]] == 2
    tables.release(0)                       # prefill side lets go
    assert pool.refcount[blocks[0]] == 1
    tables.release(1)
    assert pool.used_blocks == 0
    assert (pool.refcount[1:] == 0).all()


def test_cow_after_handoff_never_writes_shared_block():
    # the imported chain's partial frontier block stays shared with the
    # exporting slot until first write: the write must COW into a private
    # block, with the reservation booked at import time so it cannot fail
    pool, tables = _tables(num_blocks=9, n_slots=3, bpslot=4)
    prompt = [1, 2, 3, 4, 5, 6]             # frontier: block 1, 2 live rows
    keys, tail = prefix_keys(prompt, 4)
    assert tables.admit(0, keys, tail, span_blocks=2)
    tables.seal_prompt(0)
    blocks, bkeys = tables.export_slot(0)
    assert tables.import_slot(1, blocks, bkeys, live_tokens=6,
                              src_pool=pool, span_blocks=3) == []
    assert pool.cow_debt == 1               # frontier reservation booked
    shared = int(tables.read[1][1])
    cow = tables.ensure_writable(1, 6)      # first generated token
    assert cow is not None
    src_b, dst_b = cow
    assert src_b == shared and dst_b != shared
    # slot 0's view of the frontier block is untouched
    assert int(tables.read[0][1]) == shared
    assert pool.cow_debt == 0 and pool.cow_events == 1
    tables.release(0)
    tables.release(1)
    assert pool.used_blocks == 0
    assert (pool.refcount[1:] == 0).all()


def test_import_fails_without_mutation_when_full():
    src_pool, src = _tables()
    dst_pool, dst = _tables(num_blocks=3)   # 2 usable blocks
    keys, tail = prefix_keys(list(range(12)), 4)
    assert src.admit(0, keys, tail, span_blocks=3)
    src.seal_prompt(0)
    blocks, bkeys = src.export_slot(0)
    before = (dst.read.copy(), dst_pool.refcount.copy(),
              dst_pool.free_blocks)
    assert dst.import_slot(0, blocks, bkeys, live_tokens=12,
                           src_pool=src_pool, span_blocks=3) is None
    assert (dst.read == before[0]).all()
    assert (dst_pool.refcount == before[1]).all()
    assert dst_pool.free_blocks == before[2]
