"""Sharded sparse-embedding subsystem: placement math, dedup lookup,
Pallas kernel vs ref parity, sparse gradients, and bit-for-bit parity of
every sharding plan on a 1-device mesh (the multi-device parity lives in
``distributed_checks.py``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat, embeddings
from repro.embeddings import update as embed_update
from repro.kernels import ops


def _table(rows=64, dim=16, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(rows, dim)), jnp.float32)


def _zipf_ids(n, rows, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.minimum(rng.zipf(1.3, n) - 1, rows - 1), jnp.int32)


# ---------------------------------------------------------------------------
# placement math
# ---------------------------------------------------------------------------

def test_plan_shard_shapes_and_bytes():
    spec = embeddings.EmbedSpec("t", rows=128, dim=64)
    mesh = {"data": 2, "model": 4}
    assert embeddings.shard_shape(
        spec, embeddings.make_plan("replicated"), mesh) == (128, 64)
    assert embeddings.shard_shape(
        spec, embeddings.make_plan("row"), mesh) == (32, 64)
    assert embeddings.shard_shape(
        spec, embeddings.make_plan("col"), mesh) == (128, 32)
    assert embeddings.shard_shape(
        spec, embeddings.make_plan("row_col"), mesh) == (32, 32)
    # 2D sharding: per-device memory shrinks ~1/N with total shards
    full = embeddings.shard_bytes(
        spec, embeddings.make_plan("replicated"), mesh)
    two_d = embeddings.shard_bytes(
        spec, embeddings.make_plan("row_col"), mesh)
    assert two_d == full // 8


def test_plan_validation():
    with pytest.raises(ValueError):
        embeddings.EmbedPlan(kind="row")            # missing row_axis
    with pytest.raises(ValueError):
        embeddings.EmbedPlan(kind="replicated", row_axis="model")
    with pytest.raises(ValueError):
        embeddings.EmbedPlan(kind="bogus")
    spec = embeddings.EmbedSpec("t", rows=100, dim=64)
    with pytest.raises(ValueError):                 # 100 % 8 != 0
        embeddings.shard_shape(spec, embeddings.make_plan(
            "row", row_axis="model"), {"model": 8})


def test_exchange_model_sharded_beats_replicated():
    """The cost model agrees with the benchmark's claim: row/col/2D move
    fewer bytes than the replicated-dense grad all-reduce, and sparse
    sync beats dense replicated."""
    spec = embeddings.EmbedSpec("t", rows=16384, dim=64)
    mesh = {"data": 8, "model": 4}
    rep = embeddings.exchange_bytes(
        spec, embeddings.make_plan("replicated"), mesh, 128)["total"]
    for kind in ("row", "col", "row_col"):
        ex = embeddings.exchange_bytes(
            spec, embeddings.make_plan(kind), mesh, 128)["total"]
        assert ex < rep, kind
    assert embeddings.sparse_exchange_bytes(spec, mesh, 128) < rep


# ---------------------------------------------------------------------------
# dedup lookup + kernels
# ---------------------------------------------------------------------------

def test_dedup_lookup_bitwise_equals_gather():
    table = _table()
    ids = _zipf_ids(40, 64)
    want = np.asarray(table)[np.asarray(ids)]
    np.testing.assert_array_equal(
        np.asarray(embeddings.dedup_lookup(table, ids)), want)
    np.testing.assert_array_equal(
        np.asarray(embeddings.dedup_lookup(table, ids, use_kernel=True)),
        want)
    # 2D id shapes keep their leading dims
    ids2 = ids.reshape(8, 5)
    out = embeddings.dedup_lookup(table, ids2)
    assert out.shape == (8, 5, 16)
    np.testing.assert_array_equal(np.asarray(out), want.reshape(8, 5, 16))


def test_gather_kernel_matches_ref():
    table = _table(rows=128, dim=32)
    ids = _zipf_ids(48, 128)
    np.testing.assert_array_equal(
        np.asarray(ops.embedding_gather(table, ids)),
        np.asarray(ops.embedding_gather(table, ids, impl="ref")))


def test_scatter_add_kernel_matches_ref_with_duplicates():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 8, 24), jnp.int32)   # heavy dupes
    got = ops.embedding_scatter_add(x, idx, 8)
    want = ops.embedding_scatter_add(x, idx, 8, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# sparse gradients
# ---------------------------------------------------------------------------

def test_sparse_grad_from_lookup_equals_autodiff():
    table = _table()
    ids = _zipf_ids(32, 64)
    tgt = jnp.asarray(np.random.default_rng(4).normal(size=(32, 16)),
                      jnp.float32)

    def loss(t):
        return 0.5 * jnp.sum((t[ids] - tgt) ** 2)

    dense = jax.grad(loss)(table)
    dout = table[ids] - tgt                       # d loss / d lookup
    for use_kernel in (False, True):
        u, rows = embed_update.sparse_grad_from_lookup(
            dout, ids, 64, use_kernel=use_kernel)
        rebuilt = embed_update.scatter_rows(u, rows, 64)
        np.testing.assert_allclose(np.asarray(rebuilt), np.asarray(dense),
                                   rtol=1e-6, atol=1e-6)


def test_sparse_row_sync_single_device_bitwise():
    """On a 1-device mesh the rows-touched sync IS the dense gradient."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = compat.make_mesh((1,), ("data",))
    g = np.zeros((64, 16), np.float32)
    ids = np.asarray(_zipf_ids(20, 64))
    rng = np.random.default_rng(5)
    for j in ids:
        g[j] += rng.normal(size=16).astype(np.float32)

    f = shard_map(
        lambda gs, i: embed_update.sparse_row_sync(gs, i, ("data",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False)
    out = f(jnp.asarray(g), jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(out), g)


def test_row_compressor_keeps_topk_per_row():
    rows = jnp.asarray(np.random.default_rng(6).normal(size=(8, 16)),
                       jnp.float32)
    comp = embed_update.make_row_compressor("topk", k=4)
    kept = np.asarray(comp(rows))
    for r in range(8):
        nz = np.nonzero(kept[r])[0]
        assert len(nz) == 4
        # the kept entries are the 4 largest magnitudes, values unchanged
        want = np.argsort(-np.abs(np.asarray(rows[r])))[:4]
        assert set(nz) == set(want)
        np.testing.assert_array_equal(kept[r, nz], np.asarray(rows)[r, nz])


# ---------------------------------------------------------------------------
# sharding plans on a 1-device mesh: bit-for-bit vs the replicated gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", embeddings.PLANS)
def test_sharded_lookup_single_device_bitwise(kind):
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    spec = embeddings.EmbedSpec("t", rows=64, dim=16)
    plan = embeddings.make_plan(kind)
    table = _table()
    ids = _zipf_ids(32, 64)
    lk = embeddings.make_sharded_lookup(mesh, spec, plan)
    out = lk(jax.device_put(table, embeddings.named_sharding(mesh, plan)),
             ids)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(table)[np.asarray(ids)])


def test_col_plan_requires_dp_axis():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    spec = embeddings.EmbedSpec("t", rows=64, dim=16)
    plan = embeddings.make_plan("col", col_axis="model")
    with pytest.raises(ValueError):
        embeddings.make_sharded_lookup(mesh, spec, plan)
