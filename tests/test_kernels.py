"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hk,Sq,Sk,D", [
    (1, 2, 2, 32, 32, 16),
    (2, 4, 2, 64, 64, 32),       # GQA
    (1, 4, 1, 48, 80, 16),       # MQA, ragged seq (padding path)
    (2, 2, 2, 16, 128, 64),      # long kv
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, Hk, Sq, Sk, D, causal, window,
                                     dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hk, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hk, Sk, D), dtype)
    out = ops.flash_attention_bhsd(q, k, v, causal=causal, window=window,
                                   block_q=16, block_k=16)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    atol=tol, rtol=tol)


def test_flash_attention_bshd_layout():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 32, 4, 16))
    k = jax.random.normal(ks[1], (2, 32, 2, 16))
    v = jax.random.normal(ks[2], (2, 32, 2, 16))
    out = ops.flash_attention(q, k, v, block_q=16, block_k=16)
    want = ref.flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_matches_model_attention():
    """Kernel agrees with the model stack's chunked attention."""
    from repro.models import attention as attn_lib
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    out = ops.flash_attention(q, k, v, block_q=16, block_k=16)
    want = attn_lib.chunked_attention(q, k, v, causal=True, chunk=16)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# MoE router
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,E,k", [(64, 8, 2), (128, 64, 6), (96, 128, 8)])
def test_moe_router_matches_ref(T, E, k):
    logits = jax.random.normal(jax.random.PRNGKey(0), (T, E))
    g1, i1, p1 = ops.moe_router(logits, k)
    g2, i2, p2 = ref.moe_router(logits, k)
    assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6, rtol=1e-6)


def test_moe_router_gates_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    g, i, _ = ops.moe_router(logits, 4)
    assert_allclose(np.asarray(jnp.sum(g, -1)), np.ones(32), atol=1e-5)
    # indices distinct per token
    i = np.asarray(i)
    assert all(len(set(row)) == 4 for row in i)


# ---------------------------------------------------------------------------
# 1-bit compression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,block", [(8 * 512, 512), (8 * 2048, 512),
                                     (8 * 1024, 1024)])
def test_onebit_roundtrip_matches_ref(N, block):
    g = jax.random.normal(jax.random.PRNGKey(0), (N,))
    p1, s1 = ops.onebit_quantize(g, block)
    p2, s2 = ops.onebit_quantize(g, block, impl="ref")
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-6, rtol=1e-6)
    d1 = ops.onebit_dequantize(p1, s1, block)
    d2 = ops.onebit_dequantize(p2, s2, block, impl="ref")
    assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6, rtol=1e-6)


def test_onebit_sign_preservation():
    g = jax.random.normal(jax.random.PRNGKey(1), (8 * 512,)) + 0.1
    p, s = ops.onebit_quantize(g, 512)
    d = ops.onebit_dequantize(p, s, 512)
    nz = np.asarray(g) != 0
    np.testing.assert_array_equal(np.sign(np.asarray(d))[nz],
                                  np.sign(np.asarray(g))[nz])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_onebit_error_feedback_property(seed):
    """dequant(quant(g)) + residual == g exactly (error feedback closes)."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (8 * 512,))
    p, s = ops.onebit_quantize(g, 512)
    d = ops.onebit_dequantize(p, s, 512)
    resid = g - d
    assert_allclose(np.asarray(d + resid), np.asarray(g), atol=1e-6)


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,block,k", [(4096, 512, 8), (8192, 2048, 32),
                                       (2048, 256, 1)])
def test_topk_matches_ref(N, block, k):
    g = jax.random.normal(jax.random.PRNGKey(0), (N,))
    k1, r1 = ops.topk_sparsify(g, k, block)
    k2, r2 = ops.topk_sparsify(g, k, block, impl="ref")
    assert_allclose(np.asarray(k1), np.asarray(k2), atol=1e-6)
    assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 64))
def test_topk_properties(seed, k):
    g = jax.random.normal(jax.random.PRNGKey(seed), (2048,))
    kept, resid = ops.topk_sparsify(g, k, 512)
    kept, resid, g = map(np.asarray, (kept, resid, g))
    # decomposition is exact
    assert_allclose(kept + resid, g, atol=1e-7)
    # per block: at least k kept (ties included), none beyond threshold missed
    for b in range(4):
        kb = kept[b * 512:(b + 1) * 512]
        gb = g[b * 512:(b + 1) * 512]
        nz = np.count_nonzero(kb)
        assert nz >= min(k, np.count_nonzero(gb))
        # every kept magnitude >= every dropped magnitude
        dropped = np.abs(gb[kb == 0])
        if nz and dropped.size:
            assert np.abs(kb[kb != 0]).min() >= dropped.max() - 1e-7


# ---------------------------------------------------------------------------
# fused AdamW
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N", [8 * 2048, 8 * 4096])
def test_adamw_matches_ref(N):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p, g, m, v = (jax.random.normal(k, (N,)) for k in ks)
    v = jnp.abs(v)
    step = 3
    bc1, bc2 = 1 - 0.9 ** step, 1 - 0.95 ** step
    out1 = ops.adamw_update(p, g, m, v, 1e-3, bc1, bc2)
    out2 = ops.adamw_update(p, g, m, v, 1e-3, bc1, bc2, impl="ref")
    for a, b in zip(out1, out2):
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# chunked WKV6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,T,hs,chunk", [
    (2, 2, 64, 16, 16),
    (1, 4, 32, 8, 8),
    (2, 1, 96, 32, 32),
])
def test_wkv6_kernel_matches_ref(B, H, T, hs, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(kk, (B, H, T, hs)) for kk in ks[:3])
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, H, T, hs)) * 2 - 2))
    u = jax.random.normal(ks[4], (H, hs)) * 0.1
    out = ops.wkv6_chunked(r, k, v, w, u, chunk=chunk)
    want = ops.wkv6_chunked(r, k, v, w, u, impl="ref")
    assert_allclose(np.asarray(out), np.asarray(want), atol=5e-4, rtol=5e-4)


def test_wkv6_kernel_extreme_decay_stable():
    """Fast-decay channels (w -> 0) must not overflow/NaN (exponents <= 0)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    B, H, T, hs = 1, 2, 32, 8
    r, k, v = (jax.random.normal(kk, (B, H, T, hs)) for kk in ks[:3])
    w = jnp.full((B, H, T, hs), 1e-6)               # near-total decay
    u = jnp.zeros((H, hs))
    out = ops.wkv6_chunked(r, k, v, w, u, chunk=8)
    assert np.isfinite(np.asarray(out)).all()
    want = ops.wkv6_chunked(r, k, v, w, u, impl="ref")
    assert_allclose(np.asarray(out), np.asarray(want), atol=5e-4, rtol=5e-4)
