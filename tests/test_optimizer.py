"""Optimizer + schedule tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.optimizer import adamw, schedule


def test_adamw_decreases_quadratic():
    tc = TrainConfig(weight_decay=0.0, grad_clip=0.0, b1=0.9, b2=0.999)
    params = {"w": jnp.ones((8,)), "nested": ({"b": jnp.ones((3,))},)}
    opt = adamw.init_opt_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["nested"][0]["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt = adamw.adamw_apply(params, g, opt, 0.05, tc)
    assert float(loss(params)) < 0.05 * l0
    assert int(opt["step"]) == 50


def test_adamw_bias_correction_first_step():
    """After one step from zero moments, update = -lr * sign-ish(g)."""
    tc = TrainConfig(weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.zeros((4,))}
    opt = adamw.init_opt_state(params)
    g = {"w": jnp.asarray([1.0, -2.0, 3.0, -4.0])}
    new, opt = adamw.adamw_apply(params, g, opt, 0.1, tc)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               -0.1 * np.sign([1, -2, 3, -4]), rtol=1e-4)


def test_adamw_weight_decay():
    tc = TrainConfig(weight_decay=0.5, grad_clip=0.0)
    params = {"w": jnp.ones((4,))}
    opt = adamw.init_opt_state(params)
    g = {"w": jnp.zeros((4,))}
    new, _ = adamw.adamw_apply(params, g, opt, 0.1, tc)
    np.testing.assert_allclose(np.asarray(new["w"]), 1 - 0.1 * 0.5,
                               rtol=1e-5)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-5)
    assert float(norm) == 200.0


def test_adamw_bf16_params_fp32_master():
    tc = TrainConfig(weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw.init_opt_state(params)
    assert opt["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    new, opt = adamw.adamw_apply(params, g, opt, 1e-4, tc)
    assert new["w"].dtype == jnp.bfloat16
    # master accumulates below bf16 resolution
    assert float(jnp.abs(opt["master"]["w"] - 1.0).max()) > 0


def test_warmup_cosine_shape():
    lrs = [float(schedule.warmup_cosine(s, 1.0, 10, 100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 0.11
    assert lrs[-1] < 0.2
    assert max(lrs) <= 1.0 + 1e-6


def test_gemma_tuple_params_update():
    """Regression: tuple-of-dicts params (gemma blocks) survive the
    _Upd transpose."""
    tc = TrainConfig(weight_decay=0.0, grad_clip=0.0)
    params = {"blocks": ({"w": jnp.ones((2,))}, {"w": jnp.ones((2,))})}
    opt = adamw.init_opt_state(params)
    g = jax.tree.map(jnp.ones_like, params)
    new, opt2 = adamw.adamw_apply(params, g, opt, 0.1, tc)
    assert isinstance(new["blocks"], tuple) and len(new["blocks"]) == 2
    assert float(new["blocks"][0]["w"][0]) < 1.0
