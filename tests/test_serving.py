"""Serving path: batched prefill-into-cache parity + grad accumulation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro import compat
from repro.config import ParallelConfig, ShapeConfig, TrainConfig, \
    get_arch, reduced
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx

CTX = ModelCtx(attn_chunk=8)


@pytest.mark.parametrize("name", ["olmo-1b", "whisper-medium"])
def test_prefill_into_cache_matches_teacher_forced_decode(name):
    cfg = dataclasses.replace(reduced(get_arch(name)), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    B, S_p, S_max = 2, 8, 16
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S_p)),
                                   jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    cache = tf.init_cache(cfg, B, S_max)
    last_logits, cache = tf.prefill_into_cache(cfg, params, batch, cache,
                                               CTX)
    assert int(cache["len"][0]) == S_p

    # decode one more token and compare with running the extended sequence
    nxt = jnp.asarray([[5], [7]], jnp.int32)
    lg, cache = tf.decode_step(cfg, params, cache, nxt, CTX)
    full = {"tokens": jnp.concatenate([batch["tokens"], nxt], 1)}
    if cfg.encoder_layers:
        full["frames"] = batch["frames"]
    logits_full, _, _ = tf.forward(cfg, params, full, CTX)
    assert_allclose(np.asarray(lg[:, 0], np.float32),
                    np.asarray(logits_full[:, -1], np.float32),
                    atol=2e-3, rtol=2e-3)
    # prefill logits themselves match the forward too
    assert_allclose(np.asarray(last_logits, np.float32),
                    np.asarray(tf.forward(cfg, params, batch, CTX)[0][:, -1],
                               np.float32), atol=2e-3, rtol=2e-3)


def test_prefill_unsupported_family_raises():
    cfg = dataclasses.replace(reduced(get_arch("rwkv6-1.6b")),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cache = tf.init_cache(cfg, 1, 8)
    with pytest.raises(NotImplementedError):
        tf.prefill_into_cache(cfg, params,
                              {"tokens": jnp.ones((1, 4), jnp.int32)},
                              cache, CTX)


def test_grad_accumulation_matches_monolithic():
    from repro.core.hybrid import auto_plan
    from repro.optimizer import adamw
    from repro.runtime import trainer
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), num_layers=2,
                              dtype="float32")
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 16, 8, "train")
    tcfg = TrainConfig(steps=5, checkpoint_every=0, grad_clip=0.0)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(3, 200, (8, 16)), jnp.int32),
             "targets": jnp.asarray(rng.integers(3, 200, (8, 16)),
                                    jnp.int32),
             "mask": jnp.ones((8, 16), jnp.float32)}

    outs = {}
    for micro in (1, 4):
        plan = auto_plan(cfg, mesh, shape,
                         ParallelConfig(microbatches=micro))
        step, jitted, _ = trainer.make_hybrid_train_step(cfg, plan, tcfg)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_opt_state(params)
        fn = jitted(jax.eval_shape(lambda: params), batch)
        new_p, _, m = fn(params, opt, batch)
        outs[micro] = (m["loss"], new_p)
    assert_allclose(float(outs[1][0]), float(outs[4][0]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])):
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4)
