"""The trip-count-aware HLO cost analyzer: validated on crafted HLO text and
against XLA's own cost_analysis for a loop-free program."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_cost

CRAFTED = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %y = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%y), replica_groups=[4,2]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_crafted_while_trip_count():
    c = hlo_cost.analyze(CRAFTED, n_devices=8)
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert c.flops == 5 * 1024
    # all-reduce: 8*8*4 bytes, group size 2 -> ring wire 2*b*(1/2), x5
    assert c.coll_bytes["all-reduce"] == 5 * 2 * 256 * 0.5
    assert c.coll_count == 5


def test_matches_xla_cost_analysis_loop_free():
    def f(a, b):
        return jnp.tanh(a @ b)

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # jax < 0.5 returns one dict per device
        ca = ca[0]
    want = ca["flops"]
    got = hlo_cost.analyze(compiled.as_text()).flops
    np.testing.assert_allclose(got, want, rtol=0.01)


def test_scan_multiplies_flops():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    got = hlo_cost.analyze(compiled.as_text()).flops
    # 7 iterations x 2*16^3
    np.testing.assert_allclose(got, 7 * 2 * 16 ** 3, rtol=0.05)


def test_group_parsing():
    line = "  %ag = f32[16,16] all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}"
    assert hlo_cost._group_size(line, 256) == 16
    assert not hlo_cost._crosses_pod(line, 512)
    line2 = "  %ar = f32[4] all-reduce(%x), replica_groups={{0,256},{1,257}}, to_apply=%s"
    assert hlo_cost._crosses_pod(line2, 512)
    line3 = "  %ar = f32[4] all-reduce(%x), replica_groups=[1,512]<=[512], to_apply=%s"
    assert hlo_cost._crosses_pod(line3, 512)
