"""Checkpoint manager: atomic saves, keep-N GC, torn-write recovery."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "blocks": ({"a": jnp.ones((2,))},
                                  {"a": jnp.zeros((2,))})},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path, tree):
    d = str(tmp_path)
    ckpt.save(d, 10, tree)
    out = ckpt.restore(d, 10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_gc(tmp_path, tree):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.list_steps(d) == [4, 5]


def test_restore_latest_skips_torn_write(tmp_path, tree):
    d = str(tmp_path)
    ckpt.save(d, 1, tree)
    ckpt.save(d, 2, tree)
    # simulate a node dying mid-save of step 3: manifest missing
    torn = os.path.join(d, "step_0000000003")
    os.makedirs(torn)
    with open(os.path.join(torn, "arrays.npz"), "wb") as f:
        f.write(b"garbage")
    step, out = ckpt.restore_latest(d, tree)
    assert step == 2
    # and a corrupt manifest is also skipped
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        f.write("{not json")
    step, _ = ckpt.restore_latest(d, tree)
    assert step == 2


def test_restore_latest_empty_dir(tmp_path, tree):
    step, out = ckpt.restore_latest(str(tmp_path), tree)
    assert step is None and out is tree


def test_restore_casts_dtype(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.ones((4,), jnp.float32)})
    out = ckpt.restore(d, 1, {"w": jnp.ones((4,), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16


def test_manifest_contents(tmp_path, tree):
    d = str(tmp_path)
    path = ckpt.save(d, 42, tree, extra_meta={"mesh": [16, 16]})
    with open(os.path.join(path, "manifest.json")) as f:
        m = json.load(f)
    assert m["step"] == 42 and m["committed"] and m["mesh"] == [16, 16]
