"""Paged KV serving: token-exact parity with the dense layout for every
architecture family, prefix sharing, copy-on-write, pool exhaustion, and
the CacheLayout dispatch in make_backend/serve.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.cache_layout import CacheLayout
from repro.config import get_arch, reduced
from repro.models import transformer as tf
from repro.serving import engine as eng
from repro.serving import traffic
from repro.serving.block_pool import NULL_BLOCK

FAMILY_ARCHS = {"uniform": "olmo-1b", "gemma": "gemma3-1b",
                "jamba": "jamba-v0.1-52b", "rwkv6": "rwkv6-1.6b",
                "whisper": "whisper-medium"}

PAGED = CacheLayout(kind="paged", block_size=8)


def _family_setup(fam, seed=0, n=4):
    cfg = dataclasses.replace(reduced(get_arch(FAMILY_ARCHS[fam])),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 12))
        frames = None
        if cfg.encoder_layers:
            f = rng.normal(0, 0.02, (cfg.encoder_frames, cfg.d_model))
            frames = tuple(tuple(float(x) for x in row) for row in f)
        reqs.append(traffic.Request(
            rid=i, user_id=i,
            prompt=tuple(int(t) for t in
                         rng.integers(3, cfg.vocab_size, plen)),
            max_new_tokens=int(rng.integers(3, 8)), arrival=0.0,
            frames=frames))
    return cfg, params, reqs


def _run(cfg, params, reqs, layout=None, n_slots=2, max_len=64, ctx=None):
    backend = eng.make_backend(cfg, params, ctx=ctx, layout=layout)
    ecfg = eng.EngineConfig(
        n_slots=n_slots, max_len=max_len,
        layout=layout if layout is not None else CacheLayout())
    engine = eng.ServingEngine(backend, ecfg)
    outputs, _, summary = engine.run(reqs)
    return outputs, summary, engine


# ---------------------------------------------------------------------------
# token-exact parity: paged == dense for every family (the paged layout is
# pure data movement — same rows, different physical addressing)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
def test_paged_matches_dense_per_family(fam):
    cfg, params, reqs = _family_setup(fam)
    dense, sd, _ = _run(cfg, params, reqs)
    paged, sp, engine = _run(cfg, params, reqs, layout=PAGED)
    assert sp["finished"] == len(reqs) and sp["rejected"] == 0
    assert paged == dense, f"{fam}: paged tokens diverged from dense"
    assert "paged" in sp
    # every block returned to the pool after the batch drains
    assert engine.pool.used_blocks == 0
    assert (engine.pool.refcount[1:] == 0).all()


def test_paged_flash_and_int8_match_their_dense_twins():
    cfg, params, reqs = _family_setup("uniform")
    for lay in (CacheLayout(impl="flash"),
                CacheLayout(kv_bits=8),
                CacheLayout(kv_bits=8, impl="flash")):
        dense, _, _ = _run(cfg, params, reqs, layout=lay)
        paged, _, _ = _run(cfg, params, reqs,
                           layout=lay.replace(kind="paged", block_size=8))
        assert paged == dense, f"paged diverged from dense under {lay}"


def test_paged_backend_dispatch_matrix():
    cfg, params, _ = _family_setup("uniform")
    assert isinstance(eng.make_backend(cfg, params, layout=PAGED),
                      eng.PagedNativeBackend)
    assert isinstance(
        eng.make_backend(cfg, params,
                         layout=PAGED.replace(kv_bits=8)),
        eng.PagedInt8Backend)
    # chunked prefill needs the native cache-append path -> composition
    assert isinstance(
        eng.make_backend(cfg, params, prefill_chunk=8, layout=PAGED),
        eng.PagedSlots)
    cfg_g, params_g, _ = _family_setup("gemma", n=1)
    b = eng.make_backend(cfg_g, params_g, layout=PAGED.replace(kv_bits=8))
    assert isinstance(b, eng.PagedSlots)
    assert isinstance(b.inner, eng.Int8KVSlots)
    # int8 (paged or dense) on a KV-free family stays a clear error
    cfg_r, params_r, _ = _family_setup("rwkv6", n=1)
    with pytest.raises(ValueError):
        eng.make_backend(cfg_r, params_r, layout=PAGED.replace(kv_bits=8))


def test_paged_slots_pages_only_linear_kv_leaves():
    """The generic composition pools exactly the append-at-len KV leaves:
    gemma's window-bounded rings and whisper's cross-KV stay slot-resident;
    rwkv6 (no KV at all) degenerates to the identity composition."""
    cfg, params, _ = _family_setup("gemma", n=1)
    b = eng.make_backend(cfg, params, layout=PAGED)
    cache = b.init_slots(2, 64)
    n_pooled = sum(ax is not None for ax in b._specs)
    n_full = sum(1 for k in cfg.layer_kinds() if k == "attn")
    assert n_pooled == 2 * n_full           # k and v per full-attn layer
    assert cache["block_table"].shape == (2, 64 // PAGED.block_size)
    cfg_r, params_r, _ = _family_setup("rwkv6", n=1)
    br = eng.make_backend(cfg_r, params_r, layout=PAGED)
    br.init_slots(2, 64)
    assert all(ax is None for ax in br._specs)
    cfg_w, params_w, _ = _family_setup("whisper", n=1)
    bw = eng.make_backend(cfg_w, params_w, layout=PAGED)
    cache_w = bw.init_slots(2, 64)
    # cross-KV leaves keep their dense per-slot shape
    assert cache_w["cross_k"].shape[1] == 2


# ---------------------------------------------------------------------------
# prefix sharing + copy-on-write through the engine
# ---------------------------------------------------------------------------

def test_prefix_sharing_is_token_exact_and_actually_shares():
    cfg, params, _ = _family_setup("uniform")
    prompt = tuple(range(3, 3 + 13))        # 3 full 4-blocks + 1-token tail
    reqs = [traffic.Request(rid=i, user_id=i, prompt=prompt,
                            max_new_tokens=6, arrival=0.0, eos_id=-1)
            for i in range(3)]
    layout = CacheLayout(kind="paged", block_size=4)
    dense, _, _ = _run(cfg, params, reqs, n_slots=3)
    shared, ss, engine = _run(cfg, params, reqs, layout=layout, n_slots=3)
    assert shared == dense
    assert ss["paged"]["shared_hits"] > 0, "identical prompts never shared"
    # the shared whole-prompt tail forces a private copy at the first
    # generated token (copy-on-write), and never corrupts the sharer
    assert ss["paged"]["cow_events"] > 0, "shared tail never COW'd"
    assert engine.pool.used_blocks == 0     # all returned after drain
    # sharing off: same tokens, zero hits
    private, sp, _ = _run(cfg, params, reqs,
                          layout=layout.replace(prefix_sharing=False),
                          n_slots=3)
    assert private == dense and sp["paged"]["shared_hits"] == 0


def test_divergent_tails_share_only_complete_prefix_blocks():
    cfg, params, _ = _family_setup("uniform")
    base = tuple(range(3, 3 + 8))           # two full 4-blocks
    reqs = [traffic.Request(rid=0, user_id=0, prompt=base + (50, 51),
                            max_new_tokens=5, arrival=0.0, eos_id=-1),
            traffic.Request(rid=1, user_id=1, prompt=base + (60, 61, 62),
                            max_new_tokens=5, arrival=0.0, eos_id=-1)]
    layout = CacheLayout(kind="paged", block_size=4)
    dense, _, _ = _run(cfg, params, reqs, n_slots=2)
    shared, ss, _ = _run(cfg, params, reqs, layout=layout, n_slots=2)
    assert shared == dense
    # the two complete prefix blocks shared; the divergent tails must not
    assert ss["paged"]["shared_hits"] == 2


# ---------------------------------------------------------------------------
# pool pressure: oversubscribed pools queue, never corrupt
# ---------------------------------------------------------------------------

def test_pool_exhaustion_degrades_to_queueing():
    cfg, params, _ = _family_setup("uniform")
    rng = np.random.default_rng(3)
    # every span is exactly 3 blocks (12-token prompt + 8 new = 20 rows at
    # block_size 8), so a 6-block pool fits at most 2 of the 3 slots
    reqs = [traffic.Request(
        rid=i, user_id=i,
        prompt=tuple(int(t) for t in
                     rng.integers(3, cfg.vocab_size, 12)),
        max_new_tokens=8, arrival=0.0, eos_id=-1) for i in range(6)]
    layout = CacheLayout(kind="paged", block_size=8, num_blocks=6,
                         prefix_sharing=False)
    dense, _, _ = _run(cfg, params, reqs, n_slots=3)
    paged, sp, engine = _run(cfg, params, reqs, layout=layout, n_slots=3)
    assert sp["finished"] == len(reqs) and sp["rejected"] == 0
    assert paged == dense, "oversubscribed pool corrupted decode state"
    # the pool really was the constraint: fewer slots ran concurrently
    assert sp["max_concurrent_slots"] <= 2
    assert engine.pool.used_blocks == 0
    assert (engine.pool.refcount[1:] == 0).all()
    assert (engine.tables.read == NULL_BLOCK).all()


def test_impossible_request_is_rejected_not_stalled():
    cfg, params, _ = _family_setup("uniform")
    # span of 5 blocks can never fit a 4-block pool: reject, don't spin
    layout = CacheLayout(kind="paged", block_size=8, num_blocks=4,
                         prefix_sharing=False)
    reqs = [traffic.Request(rid=0, user_id=0,
                            prompt=tuple(range(3, 35)), max_new_tokens=8,
                            arrival=0.0, eos_id=-1),
            traffic.Request(rid=1, user_id=1, prompt=(5, 6, 7),
                            max_new_tokens=4, arrival=0.0, eos_id=-1)]
    _, sp, _ = _run(cfg, params, reqs, layout=layout, max_len=64)
    assert sp["rejected"] == 1
    assert sp["finished"] == 1              # the small request still ran


# ---------------------------------------------------------------------------
# summary metrics + legacy shims
# ---------------------------------------------------------------------------

def test_summary_reports_occupancy_and_kv_bytes():
    cfg, params, reqs = _family_setup("uniform")
    _, sd, _ = _run(cfg, params, reqs)
    _, sp, _ = _run(cfg, params, reqs, layout=PAGED)
    assert sd["max_concurrent_slots"] >= 1
    assert sp["max_concurrent_slots"] >= 1
    # dense prices slots*max_len always; paged prices live blocks only
    assert 0 < sp["kv_bytes_per_step"] < sd["kv_bytes_per_step"]


def test_legacy_kwargs_removed_raise_type_error():
    """The PR-6 deprecation window closed: kv=/decode_impl= are gone and
    raise a clear TypeError; the layout path is the only spelling."""
    cfg, params, reqs = _family_setup("uniform", n=2)
    with pytest.raises(TypeError):
        eng.make_backend(cfg, params, kv="int8", decode_impl="flash")
    with pytest.raises(TypeError):
        eng.make_backend(cfg, params, kv="int8")
    ecfg = eng.EngineConfig(n_slots=2, max_len=64)
    with pytest.raises(TypeError):
        eng.serve(cfg, params, reqs, ecfg, kv="int8")
    # the layout spelling serves fine
    b = eng.make_backend(cfg, params,
                         layout=CacheLayout(kv_bits=8, impl="flash"))
    assert isinstance(b, eng.Int8KVBackend)
    assert b.layout.quantized and b.layout.impl == "flash"
    out, _, summary = eng.serve(
        cfg, params, reqs,
        dataclasses.replace(ecfg, layout=CacheLayout(kv_bits=8)))
    assert summary["finished"] >= 1 and out
