"""Test bootstrap: make `src/` importable and provide a hypothesis fallback.

Keeps the tier-1 command (`PYTHONPATH=src python -m pytest -x -q`) working
as-is, while also letting a bare `pytest` run from the repo root succeed in
environments where PYTHONPATH was not exported or hypothesis is missing.
"""
import os
import sys
import types

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401  (real dependency available)
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback as _hf

    _mod = types.ModuleType("hypothesis")
    _mod.given = _hf.given
    _mod.settings = _hf.settings
    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "sampled_from", "lists"):
        setattr(_st, _name, getattr(_hf, _name))
    _mod.strategies = _st
    _mod.__fallback__ = True
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
