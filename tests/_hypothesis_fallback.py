"""Deterministic stand-in for `hypothesis` used when the real package is
absent (hermetic containers without network access).

`tests/conftest.py` installs this module as ``sys.modules["hypothesis"]``
only when ``import hypothesis`` fails, so CI environments with the real
dependency (see requirements.txt) get genuine property-based testing with
shrinking, and dependency-less environments still run every property test
over a fixed, seeded sample of the strategy space.

Only the API surface this repo's tests use is provided:

    from hypothesis import given, settings, strategies as st
    st.integers / st.floats / st.sampled_from / st.lists

Examples are drawn from a per-test ``random.Random`` seeded by the test's
qualified name, so failures are reproducible run-to-run.
"""
from __future__ import annotations

import inspect
import os
import random
import zlib

# Cap fallback examples below hypothesis' max_examples: without shrinking the
# extra draws buy little, and the suite runs JAX under every draw.
_MAX_EXAMPLES_CAP = int(os.environ.get("HYP_FALLBACK_MAX_EXAMPLES", "10"))
_DEFAULT_EXAMPLES = 10


class _Strategy:
    """A sampler: draw(rng) -> one example."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def given(*strategies: _Strategy):
    """Run the test once per drawn example (no shrinking)."""

    def decorate(fn):
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_EXAMPLES)
            # stable per-test seed: same examples every run
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(min(n, _MAX_EXAMPLES_CAP)):
                fn(*[s.draw(rng) for s in strategies])

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # empty signature so pytest doesn't mistake generated args for fixtures
        wrapper.__signature__ = inspect.Signature()
        wrapper._fallback_given = True
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Record max_examples on an already-``given``-wrapped test."""

    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return decorate
