"""Single-process tests for the pipeline-parallel training path: schedule
tables, bubble/stash cost model, stage slicing + live rebalance, microbatch
remainder handling, mrope position layout, and the analytic DP x TP x PP
step model.  Multi-device parity (1F1B vs GPipe vs serial; the full
pipelined train step) runs in ``distributed_checks.py``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import SHAPES, get_arch, reduced
from repro.core import load_balance as lb, pipeline
from repro.core.hybrid import modeled_parallel_step
from repro.models import layers as L, transformer as tf


# -- schedule tables ---------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(1, 12), st.sampled_from(["gpipe",
                                                               "1f1b"]))
def test_schedule_tables_cover_and_validate(S, M, sched):
    # the builder self-validates ring-buffer no-overwrite + dependency
    # invariants; here we check coverage and the stash bound
    fwd, bwd, depth = pipeline.schedule_tables(sched, S, M)
    for tbl in (fwd, bwd):
        for s in range(S):
            micros = tbl[:, s][tbl[:, s] >= 0]
            assert sorted(micros.tolist()) == list(range(M)), (sched, s)
    assert depth == (min(S, M) if sched == "1f1b" else M)


def test_1f1b_inflight_bounded_by_stage_depth():
    S, M = 4, 12
    fwd, bwd, depth = pipeline.schedule_tables("1f1b", S, M)
    T = fwd.shape[0]
    for s in range(S):
        inflight = 0
        peak = 0
        for t in range(T):
            if fwd[t, s] >= 0:
                inflight += 1
            if bwd[t, s] >= 0:
                inflight -= 1
            peak = max(peak, inflight)
        assert peak <= S - s, (s, peak)


def test_schedule_cost_1f1b_beats_gpipe():
    for S in (2, 4, 8):
        for M in (4, 8, 16):
            g = pipeline.schedule_cost("gpipe", S, M)
            f = pipeline.schedule_cost("1f1b", S, M)
            assert f["bubble_frac"] < g["bubble_frac"], (S, M)
            assert f["stash_micros"] <= S < g["stash_micros"] + S
            assert f["stash_micros"] == min(S, M)
            assert g["stash_micros"] == M


def test_schedule_cost_unknown_raises():
    with pytest.raises(ValueError):
        pipeline.schedule_cost("zb-h1", 4, 8)


# -- microbatching -----------------------------------------------------------

def test_microbatch_divides_and_pads():
    x = jnp.arange(12.0).reshape(6, 2)
    y = pipeline.microbatch(x, 3)
    assert y.shape == (3, 2, 2)
    with pytest.raises(ValueError):
        pipeline.microbatch(x, 4)
    yp = pipeline.microbatch(x, 4, pad=True)
    assert yp.shape == (4, 2, 2)
    np.testing.assert_array_equal(np.asarray(yp[:3]), np.asarray(y))
    assert float(jnp.abs(yp[3]).sum()) == 0.0     # zero pad rows


# -- stage balancing / rebalancing ------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.1, 10), min_size=4, max_size=20),
       st.integers(2, 4))
def test_rebalance_bounds_cover_and_monotone(times, n_stages):
    L_ = len(times)
    if L_ < n_stages:
        return
    uni = [round(i * L_ / n_stages) for i in range(n_stages + 1)]
    # observe per-stage times under the uniform carve, rebalance
    st_times = [sum(times[uni[s]:uni[s + 1]]) for s in range(n_stages)]
    nb = lb.rebalance_stages(st_times, uni)
    assert nb[0] == 0 and nb[-1] == L_
    assert all(a < b for a, b in zip(nb, nb[1:]))   # non-empty stages
    # the re-carve never worsens the inferred max-stage cost
    costs = lb.layer_costs_from_stage_times(st_times, uni)
    assert lb.stage_costs(costs, nb).max() <= \
        lb.stage_costs(costs, list(uni)).max() + 1e-9


def test_layer_costs_attribution_roundtrip():
    bounds = [0, 2, 5]
    costs = lb.layer_costs_from_stage_times([4.0, 9.0], bounds)
    np.testing.assert_allclose(costs, [2, 2, 3, 3, 3])
    np.testing.assert_allclose(lb.stage_costs(costs, bounds), [4.0, 9.0])


# -- stage slicing on the real transformer (1 device) ------------------------

def _tiny_cfg():
    return dataclasses.replace(reduced(get_arch("olmo-1b")), num_layers=6,
                               dtype="float32")


def test_stage_slice_unstack_roundtrip_and_remap_preserves_outputs():
    cfg = _tiny_cfg()
    ctx = tf.ModelCtx(attn_chunk=16)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    bounds = [0, 2, 3, 6]                     # uneven -> padded stages
    sp = tf.stage_slice_params(cfg, params["blocks"], bounds)
    assert sp["blocks"]["attn"]["wq"].shape[:2] == (3, 3)
    np.testing.assert_allclose(np.asarray(sp["mask"]),
                               [[1, 1, 0], [1, 0, 0], [1, 1, 1]])
    back = tf.unstack_stage_params(sp, bounds)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params["blocks"], back)

    stage_fn = tf.make_stage_fn(cfg, ctx)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    h = x
    for s in range(3):
        h = stage_fn(jax.tree.map(lambda a: a[s], sp), h)
    # serial reference through the stock forward body
    hr, _, _ = tf._uniform_forward(cfg, params, x,
                                   jnp.broadcast_to(jnp.arange(8)[None],
                                                    (2, 8)), ctx, False)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5)

    # live remap to new bounds computes the same function
    sp2 = tf.remap_stage_params(sp, bounds, [0, 1, 4, 6])
    h2 = x
    for s in range(3):
        h2 = stage_fn(jax.tree.map(lambda a: a[s], sp2), h2)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h), atol=1e-5)


def test_pp_partition_merge_roundtrip():
    cfg = _tiny_cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    bounds = [0, 3, 6]
    pp = tf.pp_partition_params(cfg, params, bounds)
    assert ("embed" in pp) == (not cfg.tie_embeddings)
    back = tf.pp_merge_params(cfg, pp, bounds)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, back)


def test_pp_partition_rejects_non_uniform_families():
    cfg = dataclasses.replace(reduced(get_arch("rwkv6-1.6b")),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(NotImplementedError):
        tf.pp_partition_params(cfg, params, [0, 1, 2])


# -- mrope position layout ---------------------------------------------------

def test_mrope_positions_text_only_equals_arange():
    cfg = dataclasses.replace(reduced(get_arch("qwen2-vl-2b")),
                              dtype="float32")
    pos = tf.mrope_prompt_positions(cfg, 7, None)
    assert pos.shape == (1, 7, 3)
    want = np.broadcast_to(np.arange(7)[:, None], (7, 3))
    np.testing.assert_array_equal(np.asarray(pos[0]), want)
    assert tf.mrope_next_position(7, None) == 7


def test_mrope_positions_patch_grid_layout():
    cfg = dataclasses.replace(reduced(get_arch("qwen2-vl-2b")),
                              dtype="float32")
    pos = np.asarray(tf.mrope_prompt_positions(cfg, 10, (2, 3))[0])
    # patches: t=0, h=row, w=col
    np.testing.assert_array_equal(pos[:6, 0], 0)
    np.testing.assert_array_equal(pos[:6, 1], [0, 0, 0, 1, 1, 1])
    np.testing.assert_array_equal(pos[:6, 2], [0, 1, 2, 0, 1, 2])
    # text resumes at max(gh, gw) with all components advancing together
    np.testing.assert_array_equal(pos[6], [3, 3, 3])
    np.testing.assert_array_equal(pos[9], [6, 6, 6])
    # decode continues where the prompt layout left off
    assert tf.mrope_next_position(10, (2, 3)) == 7
    with pytest.raises(ValueError):
        tf.mrope_prompt_positions(cfg, 4, (2, 3))


# -- analytic DP x TP x PP model ---------------------------------------------

def test_modeled_parallel_step_hybrid_beats_single_modes():
    cfg = get_arch("internlm2-20b")
    shape = SHAPES["train_4k"]
    n = 32
    hybrid = modeled_parallel_step(cfg, shape, dp=2, tp=4, pp=4,
                                   n_micro=8, schedule="1f1b")
    assert hybrid["fits"] and hybrid["modeled_throughput"] > 0
    for kw in ({"dp": n}, {"tp": n}, {"pp": n}):
        single = modeled_parallel_step(cfg, shape, n_micro=8,
                                       schedule="1f1b", **kw)
        assert hybrid["modeled_throughput"] >= \
            single["modeled_throughput"], (kw, single)
    # dp-only cannot even hold the optimizer state (the Table-2 baseline)
    assert not modeled_parallel_step(cfg, shape, dp=n)["fits"]
    # 1f1b's bubble advantage carries into the step model
    g = modeled_parallel_step(cfg, shape, dp=2, tp=4, pp=4, n_micro=8,
                              schedule="gpipe")
    assert hybrid["bubble_frac"] < g["bubble_frac"]
    assert hybrid["t_step_ms"] < g["t_step_ms"]


# -- rebalance-in-the-loop (observe -> rebalance -> remap) -------------------

def test_probe_stage_times_sees_skew_and_rebalance_converges():
    """A deliberately skewed 1:7 layer split is measurably imbalanced under
    the unpadded stage probe, and one rebalance round re-carves it to the
    balanced partition (homogeneous layers -> equal halves +-1)."""
    from repro.runtime import trainer
    cfg = dataclasses.replace(
        reduced(get_arch("olmo-1b"), layers=8), dtype="float32",
        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    skew = [0, 1, 8]
    pp = tf.pp_partition_params(cfg, params, skew)
    times = trainer.probe_stage_times(cfg, pp, skew, batch=4, seq=32)
    assert times[1] > times[0], times
    new = lb.rebalance_stages(times, skew)
    assert new[0] == 0 and new[-1] == 8
    assert max(new[s + 1] - new[s] for s in range(2)) <= 5, (new, times)
    # pure-timing fixpoint: exactly proportional times carve exact halves
    assert lb.rebalance_stages([1.0, 7.0], [0, 1, 8]) == [0, 4, 8]
    assert lb.rebalance_stages([4.0, 4.0], [0, 4, 8]) == [0, 4, 8]


def test_train_loop_rebalance_hook_swaps_step_fn():
    """train_loop calls rebalance_fn every K committed steps and adopts the
    returned (state, step_fn); a None return keeps the current ones."""
    from repro.config import TrainConfig
    from repro.runtime import trainer
    calls = []

    def step_a(params, opt, batch):
        return params, opt, {"loss": jnp.asarray(1.0)}

    def step_b(params, opt, batch):
        return params, opt, {"loss": jnp.asarray(2.0)}

    def rebalance(state, step_fn):
        calls.append(step_fn)
        if len(calls) == 1:
            return None                       # first probe: no change
        return state, step_b                  # second: swap the step

    tcfg = TrainConfig(steps=8, checkpoint_every=0)
    out = trainer.train_loop({"params": {}, "opt": {}},
                             iter([{}] * 8), step_a, tcfg,
                             rebalance_every=2, rebalance_fn=rebalance)
    # fired at n=2,4,6; swapped after the 2nd call (n=4)
    assert len(calls) == 3
    assert calls[:2] == [step_a, step_a] and calls[2] is step_b
    assert out.losses == [1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]
