"""Flash-decode Pallas kernel (interpret mode on CPU): length-skipping
parity with the pure-jnp oracle AND the dense model-stack decode paths
across ragged length vectors — every masking variant (full cache, sliding
window, gemma ring wraparound) plus the int8 in-kernel-dequant fusion."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref
from repro.models import attention as attn_lib
from repro.models import kvquant as kq

B, S, H, Hk, D = 4, 64, 4, 2, 16


def _qkv_cache(seed=0, s=S, h=H, hk=Hk, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, h, D), dtype)
    k = jax.random.normal(ks[1], (B, s, hk, D), dtype)
    v = jax.random.normal(ks[2], (B, s, hk, D), dtype)
    return q, k, v


RAGGED = [
    [0, 0, 0, 0],                        # every slot empty
    [S, S, S, S],                        # every slot full
    [0, 1, S // 2 + 3, S],               # empty / single / mid / full
    [5, 17, 40, 63],
]


@pytest.mark.parametrize("lengths", RAGGED)
@pytest.mark.parametrize("block_k", [8, 16, 64])
def test_flash_decode_matches_oracle_and_dense(lengths, block_k):
    q, k, v = _qkv_cache()
    lens = jnp.asarray(lengths, jnp.int32)
    out = ops.flash_decode(q, k, v, lens, block_k=block_k)
    want = ref.decode_attention(q, k, v, lens)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)
    dense = attn_lib.decode_attention(q, k, v, lens, impl="dense")
    assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5,
                    rtol=2e-5)


def test_flash_decode_gqa_and_mqa_head_groups():
    for h, hk in ((4, 1), (8, 2), (2, 2)):
        q, k, v = _qkv_cache(seed=1, h=h, hk=hk)
        lens = jnp.asarray([3, 0, 29, S], jnp.int32)
        out = ops.flash_decode(q, k, v, lens, block_k=16)
        want = ref.decode_attention(q, k, v, lens)
        assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                        rtol=2e-5, err_msg=f"H={h} Hk={hk}")


@pytest.mark.parametrize("window", [5, 12, 100])   # incl. window > len
def test_flash_decode_sliding_window_band(window):
    q, k, v = _qkv_cache(seed=2)
    lens = jnp.asarray([0, 3, 33, S], jnp.int32)
    out = ops.flash_decode(q, k, v, lens, window=window, block_k=8)
    want = ref.decode_attention(q, k, v, lens, window=window)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)
    dense = attn_lib.decode_attention(q, k, v, lens, window=window,
                                      impl="dense")
    assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5,
                    rtol=2e-5)


@pytest.mark.parametrize("window", [16, 7])
def test_flash_decode_ring_wraparound(window):
    """Ring cache of 16 rows, lengths beyond the ring (wrapped) and below
    it; wrap band masking must match the oracle and the dense ring path."""
    ring = 16
    q, k, v = _qkv_cache(seed=3, s=ring)
    lens = jnp.asarray([0, 3, ring, 37], jnp.int32)
    out = ops.flash_decode(q, k, v, lens, window=window, ring=True,
                           block_k=8)
    want = ref.decode_attention(q, k, v, lens, window=window, ring=True)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)
    dense = attn_lib.decode_attention(q, k, v, lens, window=window,
                                      ring=True, impl="dense")
    assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5,
                    rtol=2e-5)


@pytest.mark.parametrize("lengths", RAGGED)
def test_flash_decode_quant_matches_oracle_and_dense(lengths):
    q, k, v = _qkv_cache(seed=4)
    k_q, k_s = kq.quantize_kv(k)
    v_q, v_s = kq.quantize_kv(v)
    lens = jnp.asarray(lengths, jnp.int32)
    out = ops.flash_decode_quant(q, k_q, k_s, v_q, v_s, lens, block_k=16)
    want = ref.decode_attention_quant(q, k_q, k_s, v_q, v_s, lens)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)
    dense = kq.decode_attention_quant(q, k_q, k_s, v_q, v_s, lens,
                                      impl="dense")
    assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5,
                    rtol=2e-5)


def test_flash_decode_property_sweep():
    """Property-style sweep: many random ragged length vectors (always
    including 0 and S_max) stay within tight f32 tolerance of the dense
    path for both the bf16-layout and int8 kernels."""
    q, k, v = _qkv_cache(seed=5)
    k_q, k_s = kq.quantize_kv(k)
    v_q, v_s = kq.quantize_kv(v)
    rng = np.random.default_rng(0)
    for trial in range(12):
        lens = rng.integers(0, S + 1, size=B)
        lens[trial % B] = 0 if trial % 2 else S          # pin the extremes
        lens = jnp.asarray(lens, jnp.int32)
        bk = int(rng.choice([8, 16, 32]))
        out = ops.flash_decode(q, k, v, lens, block_k=bk)
        dense = attn_lib.decode_attention(q, k, v, lens, impl="dense")
        assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5,
                        rtol=2e-5, err_msg=f"trial {trial} lens {lens}")
        outq = ops.flash_decode_quant(q, k_q, k_s, v_q, v_s, lens,
                                      block_k=bk)
        denseq = kq.decode_attention_quant(q, k_q, k_s, v_q, v_s, lens)
        assert_allclose(np.asarray(outq), np.asarray(denseq), atol=2e-5,
                        rtol=2e-5, err_msg=f"trial {trial} lens {lens}")


def test_decode_attention_impl_dispatch():
    q, k, v = _qkv_cache(seed=6)
    lens = jnp.asarray([5, 0, 40, S], jnp.int32)
    flash = attn_lib.decode_attention(q, k, v, lens, impl="flash",
                                      block_k=16)
    dense = attn_lib.decode_attention(q, k, v, lens, impl="dense")
    assert_allclose(np.asarray(flash), np.asarray(dense), atol=2e-5,
                    rtol=2e-5)
    with pytest.raises(ValueError):
        attn_lib.decode_attention(q, k, v, lens, impl="nope")


def test_empty_slot_outputs_are_exact_zero():
    """len == 0 slots are defined to output zeros on every path (the dense
    softmax would otherwise emit the mean of garbage cache rows)."""
    q, k, v = _qkv_cache(seed=7)
    lens = jnp.asarray([0, 0, 7, 0], jnp.int32)
    for out in (ops.flash_decode(q, k, v, lens, block_k=8),
                attn_lib.decode_attention(q, k, v, lens, impl="dense"),
                ref.decode_attention(q, k, v, lens)):
        o = np.asarray(out)
        assert np.all(o[[0, 1, 3]] == 0.0)
        assert np.any(o[2] != 0.0)


def test_modeled_flash_bytes_below_dense_at_low_utilization():
    """The roofline term the CI serve gate checks: at mean utilization
    < 50% of S_max the flash-decode kernel's modeled bytes/step are
    strictly below the dense path's, and int8 halves them again."""
    from repro.config import get_arch
    from repro.serving.roofline import decode_attn_read_bytes
    cfg = get_arch("olmo-1b")
    rng = np.random.default_rng(1)
    lengths = rng.integers(0, 2048, size=32).tolist()   # ~25% of 4096
    dense = decode_attn_read_bytes(cfg, lengths, 4096, impl="dense")
    flash = decode_attn_read_bytes(cfg, lengths, 4096, impl="flash")
    fused = decode_attn_read_bytes(cfg, lengths, 4096, impl="flash",
                                   kv_bits=8)
    assert flash["mean_utilization"] < 0.5
    assert flash["attn_read_bytes_per_step"] \
        < dense["attn_read_bytes_per_step"]
    assert fused["attn_read_bytes_per_step"] \
        < flash["attn_read_bytes_per_step"]
    # full slots erase the advantage — dense == flash at 100% utilization
    full = [4096] * 32
    d_full = decode_attn_read_bytes(cfg, full, 4096, impl="dense")
    f_full = decode_attn_read_bytes(cfg, full, 4096, impl="flash")
    assert f_full["attn_read_bytes_per_step"] == \
        d_full["attn_read_bytes_per_step"]


def test_quant_decode_step_flash_matches_dense():
    """The fused uniform int8 decode body (kvquant.quant_decode_step) is
    logit-stable under the flash impl."""
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cache = kq.init_model_quant_cache(cfg, 2, 32)
    cache["len"] = jnp.asarray([4, 9], jnp.int32)
    toks = jnp.asarray([[5], [11]], jnp.int32)
    ld, _ = kq.quant_decode_step(cfg, params, cache, toks,
                                 tf.ModelCtx(attn_chunk=8))
    lf, _ = kq.quant_decode_step(
        cfg, params, cache, toks,
        tf.ModelCtx(attn_chunk=8, decode_impl="flash", decode_block_k=8))
    assert_allclose(np.asarray(lf), np.asarray(ld), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# paged variants: pool + block-table addressing must be a pure relabeling of
# the dense cache — same outputs under scrambled physical block placement
# ---------------------------------------------------------------------------

def _paged_from_dense(k, v, bs, num_extra=3, seed=11):
    """Scatter a dense (B, S, Hk, D) cache into a shuffled block pool.

    Physical block ids are a random permutation (never 0: the null sink),
    interleaved across slots, with spare blocks left as garbage — the
    adversarial layout a busy pool produces."""
    b, s = k.shape[0], k.shape[1]
    nb = s // bs
    total = b * nb + 1 + num_extra
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, total))[:b * nb]
    tables = jnp.asarray(perm.reshape(b, nb), jnp.int32)
    kp = (jax.random.normal(jax.random.PRNGKey(99),
                            (total, bs) + k.shape[2:]) * 10
          ).astype(k.dtype)                             # garbage everywhere
    vp = (jax.random.normal(jax.random.PRNGKey(98),
                            (total, bs) + v.shape[2:]) * 10
          ).astype(v.dtype)
    kp = kp.at[perm].set(k.reshape(b * nb, bs, *k.shape[2:]))
    vp = vp.at[perm].set(v.reshape(b * nb, bs, *v.shape[2:]))
    return kp, vp, tables


@pytest.mark.parametrize("lengths", RAGGED)
@pytest.mark.parametrize("bs", [8, 16])
def test_paged_flash_decode_matches_dense(lengths, bs):
    q, k, v = _qkv_cache(seed=8)
    kp, vp, tables = _paged_from_dense(k, v, bs)
    lens = jnp.asarray(lengths, jnp.int32)
    from repro.kernels import decode_attention as dk
    out = dk.flash_decode_attention_paged(q, kp, vp, tables, lens,
                                          interpret=True)
    want = ref.decode_attention_paged(q, kp, vp, tables, lens)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)
    dense = ops.flash_decode(q, k, v, lens, block_k=bs)
    assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5,
                    rtol=2e-5)


@pytest.mark.parametrize("window,ring", [(12, False), (7, True)])
def test_paged_flash_decode_window_and_ring(window, ring):
    s = 16 if ring else S
    q, k, v = _qkv_cache(seed=9, s=s)
    kp, vp, tables = _paged_from_dense(k, v, bs=8)
    lens = jnp.asarray([0, 3, s, 37 if ring else s - 1], jnp.int32)
    from repro.kernels import decode_attention as dk
    out = dk.flash_decode_attention_paged(q, kp, vp, tables, lens,
                                          window=window, ring=ring,
                                          interpret=True)
    want = ref.decode_attention(q, k, v, lens, window=window, ring=ring)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("lengths", RAGGED)
def test_paged_flash_decode_quant_matches_dense(lengths):
    q, k, v = _qkv_cache(seed=10)
    k_q, k_s = kq.quantize_kv(k)
    v_q, v_s = kq.quantize_kv(v)
    bs = 16
    kqp, vqp, tables = _paged_from_dense(k_q, v_q, bs)
    ksp, vsp, _ = _paged_from_dense(k_s.astype(jnp.float32)[..., None],
                                    v_s[..., None], bs)
    ksp, vsp = ksp[..., 0], vsp[..., 0]
    lens = jnp.asarray(lengths, jnp.int32)
    from repro.kernels import decode_attention as dk
    out = dk.flash_decode_attention_paged_quant(
        q, kqp, ksp, vqp, vsp, tables, lens, interpret=True)
    dense = ops.flash_decode_quant(q, k_q, k_s, v_q, v_s, lens, block_k=bs)
    assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5,
                    rtol=2e-5)


def test_unified_decode_attention_dispatch():
    """ops.decode_attention: one entry point, every cell of the
    (dense|paged) x (bf16|int8) x (ref|dense|flash) matrix agrees."""
    from repro.cache_layout import CacheLayout
    q, k, v = _qkv_cache(seed=12)
    k_q, k_s = kq.quantize_kv(k)
    v_q, v_s = kq.quantize_kv(v)
    bs = 16
    kp, vp, tables = _paged_from_dense(k, v, bs)
    kqp, vqp, _ = _paged_from_dense(k_q, v_q, bs)
    ksp, vsp, _ = _paged_from_dense(k_s[..., None], v_s[..., None], bs)
    ksp, vsp = ksp[..., 0], vsp[..., 0]
    lens = jnp.asarray([5, 0, 40, S], jnp.int32)
    golden = ref.decode_attention(q, k, v, lens)
    golden_q = ref.decode_attention_quant(q, k_q, k_s, v_q, v_s, lens)
    for impl in ("ref", "dense", "flash"):
        lay = CacheLayout(impl=impl, block_size=bs)
        out = ops.decode_attention(q, {"k": k, "v": v}, lens, layout=lay)
        assert_allclose(np.asarray(out), np.asarray(golden), atol=2e-5,
                        rtol=2e-5, err_msg=f"dense16 {impl}")
        out = ops.decode_attention(
            q, {"k": kp, "v": vp, "block_table": tables}, lens,
            layout=lay.replace(kind="paged"))
        assert_allclose(np.asarray(out), np.asarray(golden), atol=2e-5,
                        rtol=2e-5, err_msg=f"paged16 {impl}")
        out = ops.decode_attention(
            q, {"k_q": k_q, "k_s": k_s, "v_q": v_q, "v_s": v_s}, lens,
            layout=lay.replace(kv_bits=8))
        assert_allclose(np.asarray(out), np.asarray(golden_q), atol=2e-5,
                        rtol=2e-5, err_msg=f"dense8 {impl}")
        out = ops.decode_attention(
            q, {"k_q": kqp, "k_s": ksp, "v_q": vqp, "v_s": vsp,
                "block_table": tables}, lens,
            layout=lay.replace(kind="paged", kv_bits=8))
        assert_allclose(np.asarray(out), np.asarray(golden_q), atol=2e-5,
                        rtol=2e-5, err_msg=f"paged8 {impl}")
    with pytest.raises(ValueError):
        ops.decode_attention(q, {"k_q": k_q, "k_s": k_s, "v_q": v_q,
                                 "v_s": v_s}, lens,
                             layout=CacheLayout(kv_bits=8, window=8))


# ---------------------------------------------------------------------------
# speculative k-row verification: q (B, Sq, H, D) + per-slot q_lens
# ---------------------------------------------------------------------------

K_SPEC = 4


def _spec_q(seed=20, k=K_SPEC, h=H):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, k, H, D),
                             jnp.float32)


def _rowwise(call, q, lens, q_lens):
    """Ground truth for the k-row contract: row ``j`` of the fused call
    must equal a single-row decode at ``lengths + j`` (the length sequence
    row-by-row decode would present); rows ``>= q_lens`` are exact zero."""
    k = q.shape[1]
    want = np.zeros((B, k, q.shape[2], q.shape[3]), np.float32)
    ql = np.asarray(q_lens)
    for j in range(k):
        single = np.asarray(call(q[:, j:j + 1], lens + j))
        for b in range(B):
            if j < ql[b]:
                want[b, j] = single[b, 0]
    return want


@pytest.mark.parametrize("q_lens", [[1, 1, 1, 1], [4, 4, 4, 4],
                                    [1, 2, 3, 4]])
def test_spec_rows_match_single_row_full_cache(q_lens):
    """Fused k-row verification == k independent single-row decodes at
    stepped lengths (accept-0 => only row 0 live; accept-all => every
    row), dead rows exact zero — flash and dense paths, incl. len near
    S_max (the deepest live row touches the last cache row)."""
    q, k, v = _qkv_cache(seed=21)
    qk = _spec_q(seed=22)
    lens = jnp.asarray([1, 5, 40, S - K_SPEC], jnp.int32)
    ql = jnp.asarray(q_lens, jnp.int32)
    want = _rowwise(lambda qj, lj: ops.flash_decode(qj, k, v, lj,
                                                    block_k=16),
                    qk, lens, ql)
    out = ops.flash_decode(qk, k, v, lens, block_k=16, q_lens=ql)
    assert_allclose(np.asarray(out), want, atol=2e-5, rtol=2e-5)
    dense = attn_lib.decode_attention(qk, k, v, lens, impl="dense",
                                      q_lens=ql)
    assert_allclose(np.asarray(dense), want, atol=2e-5, rtol=2e-5)
    oracle = ref.decode_attention(qk, k, v, lens, q_lens=ql)
    assert_allclose(np.asarray(oracle), want, atol=2e-5, rtol=2e-5)
    qlm = np.asarray(ql)
    for b in range(B):
        assert np.all(np.asarray(out)[b, qlm[b]:] == 0.0), \
            f"slot {b}: dead draft rows must be exact zero"


@pytest.mark.parametrize("window", [2, 7])      # window < k and window > k
def test_spec_rows_sliding_window_smaller_than_draft(window):
    q, k, v = _qkv_cache(seed=23)
    qk = _spec_q(seed=24)
    lens = jnp.asarray([1, 5, 40, S - K_SPEC], jnp.int32)
    ql = jnp.asarray([1, 4, 2, 4], jnp.int32)
    want = _rowwise(
        lambda qj, lj: ops.flash_decode(qj, k, v, lj, window=window,
                                        block_k=8), qk, lens, ql)
    out = ops.flash_decode(qk, k, v, lens, window=window, block_k=8,
                           q_lens=ql)
    assert_allclose(np.asarray(out), want, atol=2e-5, rtol=2e-5)
    dense = attn_lib.decode_attention(qk, k, v, lens, window=window,
                                      impl="dense", q_lens=ql)
    assert_allclose(np.asarray(dense), want, atol=2e-5, rtol=2e-5)


def test_spec_rows_ring_wraparound_mid_draft():
    """Gemma ring sized window + k - 1 (the spec margin): draft rows whose
    ring positions wrap mid-draft still reduce to the stepped single-row
    decode."""
    ring, window = 16, 13                       # margin = K_SPEC - 1
    q, k, v = _qkv_cache(seed=25, s=ring)
    qk = _spec_q(seed=26)
    # lengths past the ring: every draft row wraps
    lens = jnp.asarray([2, 12, 30, 37], jnp.int32)
    ql = jnp.asarray([4, 3, 1, 4], jnp.int32)
    want = _rowwise(
        lambda qj, lj: ops.flash_decode(qj, k, v, lj, window=window,
                                        ring=True, block_k=8),
        qk, lens, ql)
    out = ops.flash_decode(qk, k, v, lens, window=window, ring=True,
                           block_k=8, q_lens=ql)
    assert_allclose(np.asarray(out), want, atol=2e-5, rtol=2e-5)
    dense = attn_lib.decode_attention(qk, k, v, lens, window=window,
                                      ring=True, impl="dense", q_lens=ql)
    assert_allclose(np.asarray(dense), want, atol=2e-5, rtol=2e-5)


def test_spec_rows_int8_dense_and_paged():
    q, k, v = _qkv_cache(seed=27)
    qk = _spec_q(seed=28)
    k_q, k_s = kq.quantize_kv(k)
    v_q, v_s = kq.quantize_kv(v)
    lens = jnp.asarray([1, 7, 33, S - K_SPEC], jnp.int32)
    ql = jnp.asarray([2, 4, 1, 3], jnp.int32)
    want = _rowwise(
        lambda qj, lj: ops.flash_decode_quant(qj, k_q, k_s, v_q, v_s, lj,
                                              block_k=16), qk, lens, ql)
    out = ops.flash_decode_quant(qk, k_q, k_s, v_q, v_s, lens, block_k=16,
                                 q_lens=ql)
    assert_allclose(np.asarray(out), want, atol=2e-5, rtol=2e-5)
    dense = kq.decode_attention_quant(qk, k_q, k_s, v_q, v_s, lens,
                                      q_lens=ql)
    assert_allclose(np.asarray(dense), want, atol=2e-5, rtol=2e-5)
    # paged int8 through the unified layout dispatch
    from repro.cache_layout import CacheLayout
    bs = 16
    kqp, vqp, tables = _paged_from_dense(k_q, v_q, bs)
    ksp, vsp, _ = _paged_from_dense(k_s[..., None], v_s[..., None], bs)
    ksp, vsp = ksp[..., 0], vsp[..., 0]
    for impl in ("dense", "flash"):
        outp = ops.decode_attention(
            qk, {"k_q": kqp, "k_s": ksp, "v_q": vqp, "v_s": vsp,
                 "block_table": tables}, lens,
            layout=CacheLayout(kind="paged", kv_bits=8, impl=impl,
                               block_size=bs), q_lens=ql)
        assert_allclose(np.asarray(outp), want, atol=2e-5, rtol=2e-5,
                        err_msg=f"paged8 {impl}")


def test_spec_rows_paged_block_boundary():
    """Draft spans crossing physical block boundaries (len % bs near bs)
    read the right blocks for every row."""
    from repro.cache_layout import CacheLayout
    q, k, v = _qkv_cache(seed=29)
    qk = _spec_q(seed=30)
    bs = 8
    kp, vp, tables = _paged_from_dense(k, v, bs)
    # rows straddle a boundary: len+j crosses a multiple of bs mid-draft
    lens = jnp.asarray([7, 8, 15, 39], jnp.int32)
    ql = jnp.asarray([4, 4, 3, 4], jnp.int32)
    want = _rowwise(
        lambda qj, lj: ops.decode_attention(
            qj, {"k": kp, "v": vp, "block_table": tables}, lj,
            layout=CacheLayout(kind="paged", impl="dense", block_size=bs)),
        qk, lens, ql)
    for impl in ("dense", "flash"):
        out = ops.decode_attention(
            qk, {"k": kp, "v": vp, "block_table": tables}, lens,
            layout=CacheLayout(kind="paged", impl=impl, block_size=bs),
            q_lens=ql)
        assert_allclose(np.asarray(out), want, atol=2e-5, rtol=2e-5,
                        err_msg=impl)


def test_spec_rows_property_sweep():
    """Random ragged (lengths, q_lens) pairs — always pinning the accept-0
    (q_len 1) and accept-all (q_len k) extremes — keep flash == dense ==
    stepped single-row across the full and int8 variants."""
    q, k, v = _qkv_cache(seed=31)
    k_q, k_s = kq.quantize_kv(k)
    v_q, v_s = kq.quantize_kv(v)
    rng = np.random.default_rng(7)
    for trial in range(8):
        qk = _spec_q(seed=40 + trial)
        lens = rng.integers(1, S - K_SPEC + 1, size=B)
        ql = rng.integers(1, K_SPEC + 1, size=B)
        ql[trial % B] = 1 if trial % 2 else K_SPEC      # pin the extremes
        lens_j = jnp.asarray(lens, jnp.int32)
        ql_j = jnp.asarray(ql, jnp.int32)
        want = _rowwise(lambda qj, lj: ops.flash_decode(qj, k, v, lj,
                                                        block_k=16),
                        qk, lens_j, ql_j)
        out = ops.flash_decode(qk, k, v, lens_j, block_k=16, q_lens=ql_j)
        assert_allclose(np.asarray(out), want, atol=2e-5, rtol=2e-5,
                        err_msg=f"trial {trial} lens {lens} ql {ql}")
        dense = attn_lib.decode_attention(qk, k, v, lens_j, impl="dense",
                                          q_lens=ql_j)
        assert_allclose(np.asarray(dense), want, atol=2e-5, rtol=2e-5,
                        err_msg=f"trial {trial} dense")
        wq = _rowwise(
            lambda qj, lj: ops.flash_decode_quant(qj, k_q, k_s, v_q, v_s,
                                                  lj, block_k=16),
            qk, lens_j, ql_j)
        outq = ops.flash_decode_quant(qk, k_q, k_s, v_q, v_s, lens_j,
                                      block_k=16, q_lens=ql_j)
        assert_allclose(np.asarray(outq), wq, atol=2e-5, rtol=2e-5,
                        err_msg=f"trial {trial} int8")


def test_verify_greedy_accept_semantics():
    """verify_greedy: accepts == 1 + length of the matched draft prefix,
    clamped to q_lens — the accept-0-of-k case still commits the row-0
    emission (one token, exactly single-step decode)."""
    from repro.models import transformer as tf
    V = 11
    g = np.array([[3, 5, 7, 2], [3, 5, 7, 2], [3, 5, 7, 2]])
    logits = np.full((3, 4, V), -10.0, np.float32)
    for b in range(3):
        for j in range(4):
            logits[b, j, g[b, j]] = 10.0
    toks = np.array([
        [1, 9, 9, 9],       # no draft matches -> accept 1
        [1, 3, 5, 7],       # full match -> accept 4
        [1, 3, 5, 9],       # 2-prefix matches -> accept 3
    ], np.int32)
    acc = tf.verify_greedy(jnp.asarray(toks), jnp.asarray(logits),
                           jnp.asarray([4, 4, 4], jnp.int32))
    assert list(np.asarray(acc)) == [1, 4, 3]
    # q_lens caps the accept even when later rows would match
    acc = tf.verify_greedy(jnp.asarray(toks), jnp.asarray(logits),
                           jnp.asarray([4, 2, 1], jnp.int32))
    assert list(np.asarray(acc)) == [1, 2, 1]
