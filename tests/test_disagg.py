"""Disaggregated prefill/decode serving: router policy determinism,
prefill-burst workload properties, windowed percentiles, and the
end-to-end token-exactness of the KV handoff against one interleaved
engine.  The per-family handoff matrix lives in the serve benchmark
artifact; here one fast family keeps the invariant under pytest."""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cache_layout import CacheLayout
from repro.config import get_arch, reduced
from repro.models import transformer as tf
from repro.obs import MetricsRegistry, Tracer
from repro.serving import engine as eng
from repro.serving import metrics as sm
from repro.serving import traffic
from repro.serving.disagg import (DisaggServer, Router, RouterConfig,
                                  build_disagg)


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_cache():
    jax.clear_caches()


PAGED = CacheLayout(kind="paged", block_size=8)


def _model(arch="olmo-1b"):
    cfg = dataclasses.replace(reduced(get_arch(arch)), dtype="float32")
    return cfg, tf.init_params(jax.random.PRNGKey(0), cfg)


def _requests(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [traffic.Request(
        rid=i, user_id=i,
        prompt=tuple(int(t) for t in rng.integers(
            3, cfg.vocab_size, int(rng.integers(4, 12)))),
        max_new_tokens=int(rng.integers(3, 8)),
        arrival=0.04 * i) for i in range(n)]


# ---------------------------------------------------------------------------
# prefill-burst workload properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 16),
       st.floats(0.05, 2.0))
def test_prefill_burst_deterministic(seed, burst_n, burst_start):
    cfg = traffic.PrefillBurstConfig(seed=seed, burst_n=burst_n,
                                     burst_start=burst_start)
    a = traffic.generate_prefill_burst(cfg)
    b = traffic.generate_prefill_burst(cfg)
    assert a == b                           # same cfg -> identical workload
    assert len(a) == cfg.background.n_requests + burst_n
    # arrivals sorted; rid-tiebreak makes the order total
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prefill_burst_timing_and_lengths(seed):
    cfg = traffic.PrefillBurstConfig(seed=seed)
    reqs = traffic.generate_prefill_burst(cfg)
    burst = [r for r in reqs if r.rid >= cfg.background.n_requests]
    assert len(burst) == cfg.burst_n
    # every burst arrival is after burst_start, prompts in the long band,
    # all interactive, and on fresh user ids (no history reuse with the
    # background stream)
    for r in burst:
        assert r.arrival > cfg.burst_start
        assert cfg.burst_prompt_min <= len(r.prompt) \
            <= cfg.burst_prompt_max
        assert r.max_new_tokens == cfg.burst_new_tokens
        assert r.slo is traffic.INTERACTIVE_TIER
        assert r.user_id >= cfg.background.n_users
    # the background half is byte-identical to generate(background)
    bg = [r for r in reqs if r.rid < cfg.background.n_requests]
    assert sorted(bg, key=lambda r: r.rid) == \
        traffic.generate(cfg.background)


def test_prefill_burst_validation():
    with pytest.raises(ValueError):
        traffic.generate_prefill_burst(traffic.PrefillBurstConfig(
            burst_prompt_min=40, burst_prompt_max=32))


# ---------------------------------------------------------------------------
# windowed percentiles
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(1e-4, 10.0), min_size=1, max_size=32),
       st.sampled_from([50, 90, 99]))
def test_windowed_percentile_exact_while_window_holds_all(xs, q):
    win = sm.WindowedLatency(MetricsRegistry(), "r", window=64)
    for x in xs:
        win.observe_ttft(x)
        win.observe_tpot(x / 10)
    assert win.ttft_p(q) == pytest.approx(
        float(np.percentile(np.asarray(xs), q)), rel=1e-9)
    assert win.tpot_p(q) == pytest.approx(
        float(np.percentile(np.asarray(xs) / 10, q)), rel=1e-9)


def test_windowed_percentile_slides():
    win = sm.WindowedLatency(MetricsRegistry(), "r", window=4)
    for x in (100.0, 100.0, 100.0, 100.0, 1.0, 1.0, 1.0, 1.0):
        win.observe_ttft(x)
    # the old regime aged out of the window entirely
    assert win.ttft_p(99) == pytest.approx(1.0)
    assert np.isnan(win.tpot_p(50))         # no samples yet


def test_windowed_registry_backing():
    # the window rides named registry histograms, so the trace exporter's
    # metrics snapshot shows the same samples the router scored
    reg = MetricsRegistry()
    win = sm.WindowedLatency(reg, "decode0", window=8)
    win.observe_ttft(0.25)
    assert reg.histogram("decode0.ttft_window").count == 1


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

class _StubEngine:
    def __init__(self, name, role, queued=0, active=0, remaining=0):
        self.name, self.role = name, role
        self.queue = type("Q", (), {"__len__": lambda s: queued})()
        self.n_active = active
        self.ecfg = type("C", (), {"n_slots": 4, "max_len": 64})()
        self.slot_req = [object()] * active + [None] * (4 - active)
        self.slot_remaining = [remaining] * 4
        self.handoff_inbox = []
        self.win = None


def test_router_round_robin_cycles_deterministically():
    engines = [_StubEngine(f"p{i}", "prefill") for i in range(3)]
    r = Router(engines, RouterConfig(policy="round_robin"))
    picks = [r.route(None).name for _ in range(6)]
    assert picks == ["p0", "p1", "p2", "p0", "p1", "p2"]


def test_router_least_loaded_prefers_idle_replica():
    engines = [_StubEngine("p0", "prefill", queued=3, active=4),
               _StubEngine("p1", "prefill", queued=0, active=1),
               _StubEngine("p2", "prefill", queued=2, active=2)]
    r = Router(engines, RouterConfig(policy="least_loaded"))
    assert r.route(None).name == "p1"
    # ties break on name order, so routing never depends on dict order
    tied = [_StubEngine("b", "prefill"), _StubEngine("a", "prefill")]
    assert Router(tied, RouterConfig(policy="least_loaded")) \
        .route(None).name == "a"


def test_router_slo_policy_penalizes_slow_tail():
    reg = MetricsRegistry()
    fast = _StubEngine("fast", "both")
    slow = _StubEngine("slow", "both")
    fast.win = sm.WindowedLatency(reg, "fast")
    slow.win = sm.WindowedLatency(reg, "slow")
    for _ in range(8):
        fast.win.observe_ttft(0.01)
        slow.win.observe_ttft(5.0)          # drifting tail
    r = Router([slow, fast], RouterConfig(policy="slo"))
    assert r.route(None).name == "fast"
    with pytest.raises(ValueError):
        RouterConfig(policy="fastest")
    with pytest.raises(ValueError):
        Router([_StubEngine("d", "decode")], RouterConfig())


# ---------------------------------------------------------------------------
# end-to-end: handoff token-exactness + pool drain + obs coherence
# ---------------------------------------------------------------------------

def test_disagg_token_exact_and_pools_drain():
    cfg, params = _model()
    reqs = _requests(cfg)
    ecfg = eng.EngineConfig(n_slots=2, max_len=64, layout=PAGED)
    single = eng.ServingEngine(
        eng.make_backend(cfg, params, layout=PAGED), ecfg,
        traffic.Clock(0.01, 0.05))
    out_1, recs_1, _ = single.run(reqs)
    srv = build_disagg(cfg, params, n_prefill=1, n_decode=1, ecfg=ecfg,
                       clock=traffic.Clock(0.01, 0.05, 0.002))
    out_n, recs_n, s = srv.run(reqs)
    assert out_n == out_1                   # bit-identical token streams
    assert s["disagg"]["handoffs"] == len(reqs)
    assert [r.rid for r in recs_n] == [r.rid for r in recs_1]
    assert all(r.tokens_out == r1.tokens_out
               for r, r1 in zip(recs_n, recs_1))
    for e in srv.engines:
        assert e.pool.used_blocks == 0
        assert (e.pool.refcount[1:] == 0).all()
        assert e.pool.cow_debt == 0


def test_disagg_traced_run_has_handoff_spans():
    cfg, params = _model()
    reqs = _requests(cfg, n=3)
    ecfg = eng.EngineConfig(n_slots=2, max_len=64, layout=PAGED)
    tracer, reg = Tracer(), MetricsRegistry()
    srv = build_disagg(cfg, params, n_prefill=1, n_decode=1, ecfg=ecfg,
                       clock=traffic.Clock(0.01, 0.05, 0.002),
                       tracer=tracer, metrics=reg)
    _, _, s = srv.run(reqs)
    names = [e["name"] for e in tracer.events]
    assert names.count("pool.handoff") == 2 * len(reqs)   # out + in
    spans = [e for e in tracer.events
             if e["ph"] == "X" and e["name"] == "req.handoff"]
    assert len(spans) == len(reqs)
    assert all(e["dur"] > 0 for e in spans)
    # per-replica load gauges were stamped on each engine's own clock
    snap = s["obs"]["metrics"]
    for name in ("prefill0", "decode0"):
        assert f"{name}.queue_depth" in snap["gauges"]
        assert f"{name}.in_flight_tokens" in snap["gauges"]
    assert s["disagg"]["replicas"]["prefill0"]["handoffs_out"] == len(reqs)
    assert s["disagg"]["replicas"]["decode0"]["handoffs_in"] == len(reqs)


def test_disagg_replica_pool_prefix_sharing_still_works():
    # two requests with one shared prompt prefix, arriving back-to-back.
    # The prefill tier frees each slot the moment it exports (that is the
    # TTFT win), so sharing there is incidental; the invariant is that
    # the handoff *re-establishes* sharing on the decode tier — the
    # second import dedupes against the first request's re-sealed blocks
    # by content key
    cfg, params = _model()
    rng = np.random.default_rng(7)
    base = tuple(int(t) for t in rng.integers(3, cfg.vocab_size, 16))
    # generations long enough that the first import is still decoding
    # (blocks sealed + live) when the second one lands
    reqs = [traffic.Request(rid=i, user_id=0, prompt=base + (10 + i,),
                            max_new_tokens=16, arrival=0.0)
            for i in range(2)]
    ecfg = eng.EngineConfig(n_slots=2, max_len=64, layout=PAGED)
    srv = build_disagg(cfg, params, n_prefill=1, n_decode=1, ecfg=ecfg,
                       clock=traffic.Clock(0.01, 0.05, 0.002))
    out, _, s = srv.run(reqs)
    single = eng.ServingEngine(
        eng.make_backend(cfg, params, layout=PAGED), ecfg,
        traffic.Clock(0.01, 0.05))
    assert out == single.run(reqs)[0]
    rep = s["disagg"]["replicas"]
    assert rep["decode0"]["paged"]["shared_hits"] > 0


def test_disagg_both_role_replicas_load_balance():
    # n_decode=0: N interleaved replicas behind the router — every
    # request stays where it prefilled, no handoffs, still token-exact
    cfg, params = _model()
    reqs = _requests(cfg, n=6)
    ecfg = eng.EngineConfig(n_slots=2, max_len=64, layout=PAGED)
    srv = build_disagg(cfg, params, n_prefill=2, n_decode=0, ecfg=ecfg,
                       clock=traffic.Clock(0.01, 0.05))
    out, recs, s = srv.run(reqs)
    assert s["disagg"]["handoffs"] == 0
    single = eng.ServingEngine(
        eng.make_backend(cfg, params, layout=PAGED),
        dataclasses.replace(ecfg, n_slots=4), traffic.Clock(0.01, 0.05))
    assert out == single.run(reqs)[0]
    per = [r["prefills"] for r in s["disagg"]["replicas"].values()]
    assert sum(per) == len(reqs) and all(p > 0 for p in per)


def test_disagg_requires_paged_layout():
    cfg, params = _model()
    with pytest.raises(ValueError):
        build_disagg(cfg, params,
                     ecfg=eng.EngineConfig(n_slots=2, max_len=64))


def test_tier_roles_constrain_engine():
    cfg, params = _model()
    ecfg = eng.EngineConfig(n_slots=2, max_len=64, layout=PAGED)
    with pytest.raises(ValueError):
        eng.ServingEngine(eng.make_backend(cfg, params, layout=PAGED),
                          ecfg, role="sidecar")
    with pytest.raises(ValueError):         # tier roles need block tables
        eng.ServingEngine(
            eng.make_backend(cfg, params, layout=CacheLayout()),
            eng.EngineConfig(n_slots=2, max_len=64), role="prefill")


# ---------------------------------------------------------------------------
# modeled tier split
# ---------------------------------------------------------------------------

def test_modeled_tier_split_is_heterogeneous():
    from repro.serving.roofline import (modeled_prefill_step,
                                        modeled_tier_split)
    full = get_arch("olmo-1b")
    p = modeled_prefill_step(full, 1024)
    assert p["bound"] == "compute"          # long prompts: matmul-bound
    s = modeled_tier_split(full, n_slots=64, cache_len=2048,
                           prompt_len=1024)
    assert s["decode"]["bound"] == "memory"
    assert s["split_is_heterogeneous"]
    assert s["handoff_s"] > 0
    # one handoff costs less than the prefill stall it removes
    assert s["stall_vs_handoff"] > 1.0
